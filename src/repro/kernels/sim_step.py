"""Pallas kernel for the batch simulator's masked primitive-update step.

This is the one dense elementwise block the device simulation engine
(:mod:`repro.core.jax_sim`) executes *every* outer iteration: given the
primitive each lane decided to run (work segment / idle segment /
checkpoint), the pre-resolved next-fault date, and the lane state, it

1. applies the fault check (a fault at or before the primitive's target
   interrupts work/idle; a fault strictly before a checkpoint's end date
   aborts it — the exact-date prediction semantics of the scalar oracle),
2. advances the clock and the saved/unsaved/period-work accounting with
   masked updates, and
3. reports the outcome flags (faulted / ok / job finished / checkpoint
   committed / regular checkpoint) packed in one int32 bitfield.

Lane state is laid out as ``(rows, 128)`` float slabs (rows a multiple of
the sublane tile), so the kernel is a pure VPU elementwise pass.  On
non-TPU backends it runs in interpret mode (exact semantics); the pure-jnp
:func:`primitive_update` is both the kernel body and the no-Pallas
fallback, so the two paths are bit-identical by construction.

Primitive codes extend ``repro.core.batch_sim``'s 0 noop / 1 work /
2 idle / 3 checkpoint with 4 = work *not* credited toward the regular
period (the device engine folds the NumPy engine's separate ``credit``
flag into the primitive code — one less lane array per iteration).

The module also hosts the *sampling step* of the device trace generator
(``trace_mode="device"``): a counter-based Threefry-2x32 stream cipher
(bit-identical to the NumPy reference in :mod:`repro.core.events`),
inverse-CDF inter-arrival transforms for the exponential / Weibull /
lognormal / uniform families, and :func:`stream_advance` — the fused
"draw the next event of a renewal stream" update.  Like the primitive
update it has a Pallas entry (:func:`masked_stream_advance`) whose body
is the pure-jnp function itself, so the two paths are bit-identical.
"""

from __future__ import annotations

from functools import partial
from math import gamma as _gamma

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import numpy as np

from ..core.events import (
    _SM_GAMMA, _SM_MIX1, _SM_MIX2, _TF_PARITY, _TF_ROTATIONS, THREEFRY_ROUNDS,
    LAW_EXPONENTIAL, LAW_LOGNORMAL, LAW_UNIFORM, LAW_WEIBULL,
)

__all__ = [
    "PRIM_NOOP",
    "PRIM_WORK",
    "PRIM_IDLE",
    "PRIM_CKPT",
    "PRIM_WORK_NC",
    "FLAG_FAULTED",
    "FLAG_OK",
    "FLAG_FIN",
    "FLAG_CKPT_OK",
    "FLAG_REG",
    "primitive_update",
    "masked_primitive_update",
    "threefry2x32",
    "splitmix64",
    "stream_key",
    "counter_words",
    "uniform24",
    "counter_uniform",
    "counter_uniform2",
    "gap_transform",
    "gap_transform_indexed",
    "stream_advance",
    "masked_stream_advance",
    "cell_gather",
    "segment_cell_sums",
]

#: primitive kinds (0-3 shared with repro.core.batch_sim's _PR_* codes;
#: 4 is the device engine's uncredited-work variant of PRIM_WORK)
PRIM_NOOP, PRIM_WORK, PRIM_IDLE, PRIM_CKPT, PRIM_WORK_NC = 0, 1, 2, 3, 4

#: outcome bitfield
FLAG_FAULTED = 1  # a fault interrupted the primitive
FLAG_OK = 2  # primitive completed without fault
FLAG_FIN = 4  # the work segment finished the job
FLAG_CKPT_OK = 8  # a checkpoint committed (saved <- saved + unsaved)
FLAG_REG = 16  # ... and it was a *regular* (period-resetting) checkpoint


# repro-lint: jit-root
def primitive_update(
    prim, cont, target, ckend, nf, t, saved, unsaved, pw, W, DR,
    *, eps: float, reg_cont: int, stream=None, gap=None,
):
    """One masked primitive execution; mirrors the NumPy engine's
    execute-one-primitive-per-lane block statement for statement.

    ``target`` must already be capped at job completion and ``ckend``
    fixed from the pre-fault-resolution clock (the caller replicates the
    scalar oracle's order of operations); ``nf`` is each lane's next
    pending fault after stale-fault resolution.  Returns
    ``(t, saved, unsaved, period_work, flags)``.

    Device trace mode fuses the generation step in: ``stream`` carries
    the strike cursor ``(key, ctr, tm, mean, horizon)`` (with ``nf ==
    tm``) and ``gap`` the static ``(kind, param)`` of the fault law; the
    consumed fault is then refilled by one counter draw where the
    primitive faulted, and the advanced ``(ctr, tm)`` pair is appended to
    the return tuple — sampling happens inside the (Pallas) hot step
    instead of a second kernel launch per iteration.
    """
    creditb = prim == PRIM_WORK
    workm = creditb | (prim == PRIM_WORK_NC)
    idlem = prim == PRIM_IDLE
    ckm = prim == PRIM_CKPT
    res = workm | idlem | ckm

    faulted = ((workm | idlem) & (nf <= target)) | (ckm & (nf < ckend))
    ok = res & ~faulted

    t1 = jnp.where(faulted, nf + DR, t)
    unsaved1 = jnp.where(faulted, 0.0, unsaved)
    pw1 = jnp.where(faulted, 0.0, pw)

    wok = workm & ok
    dt = target - t
    unsaved2 = jnp.where(wok, unsaved1 + dt, unsaved1)
    pw2 = jnp.where(wok & creditb, pw1 + dt, pw1)
    t2 = jnp.where(wok, target, t1)
    fin = wok & (saved + unsaved2 >= W - eps)

    iok = idlem & ok
    t3 = jnp.where(iok, target, t2)

    cok = ckm & ok
    t4 = jnp.where(cok, ckend, t3)
    saved2 = jnp.where(cok, saved + unsaved2, saved)
    unsaved3 = jnp.where(cok, 0.0, unsaved2)
    reg = cok & (cont == reg_cont)
    pw3 = jnp.where(reg, 0.0, pw2)

    flags = (
        faulted.astype(jnp.int32) * FLAG_FAULTED
        + ok.astype(jnp.int32) * FLAG_OK
        + fin.astype(jnp.int32) * FLAG_FIN
        + cok.astype(jnp.int32) * FLAG_CKPT_OK
        + reg.astype(jnp.int32) * FLAG_REG
    )
    if stream is None:
        return t4, saved2, unsaved3, pw3, flags
    if len(stream) == 5:
        skey, sctr, stm, smean, shorizon = stream
        slaw = slp = None
    else:  # law-multiplexed: per-lane law index + (s1, s2) shape slots
        skey, sctr, stm, smean, shorizon, slaw, s1, s2 = stream
        slp = (s1, s2)
    sctr, stm = stream_advance(
        faulted, sctr, stm, skey, smean, shorizon,
        kind=gap[0], param=gap[1], law=slaw, lp=slp,
    )
    return t4, saved2, unsaved3, pw3, flags, sctr, stm


# --------------------------------------------------------------------------- #
# Counter-based RNG sampling step (device trace generation)
# --------------------------------------------------------------------------- #
# repro-twin: repro.core.events.threefry2x32
# repro-lint: jit-root
def threefry2x32(k0, k1, c0, c1, rounds: int = THREEFRY_ROUNDS):
    """Threefry-2x32 over uint32 arrays; the jnp twin of
    :func:`repro.core.events.threefry2x32` (bit-identical by the shared
    rotation/key-schedule constants; pinned by a known-answer test)."""
    k0 = jnp.asarray(k0, jnp.uint32)
    k1 = jnp.asarray(k1, jnp.uint32)
    x0 = jnp.asarray(c0, jnp.uint32)
    x1 = jnp.asarray(c1, jnp.uint32)
    ks = (k0, k1, k0 ^ k1 ^ jnp.uint32(_TF_PARITY))
    x0 = x0 + ks[0]
    x1 = x1 + ks[1]
    for i in range(rounds):
        r = _TF_ROTATIONS[(i // 4) % 2][i % 4]
        x0 = x0 + x1
        x1 = (x1 << r) | (x1 >> (32 - r))
        x1 = x1 ^ x0
        if i % 4 == 3:
            s = i // 4 + 1
            x0 = x0 + ks[s % 3]
            x1 = x1 + ks[(s + 1) % 3] + jnp.uint32(s)
    return x0, x1


# repro-twin: repro.core.events.uniform24
# repro-lint: jit-root
def uniform24(bits, dtype):
    """uint32 words -> uniforms in the open interval (0, 1) (top 24 bits,
    half-ulp centered); see the NumPy twin in ``core.events``."""
    return (
        (bits >> 8).astype(dtype) + jnp.asarray(0.5, dtype)
    ) * jnp.asarray(2.0**-24, dtype)


# repro-twin: repro.core.events.splitmix64
# repro-lint: jit-root
def splitmix64(key64, ctr):
    """Counter-indexed SplitMix64 draw (jnp twin of
    ``core.events.splitmix64``): 64 output bits as (high, low) uint32
    words.  The per-event hot path — ~10 integer ops against the ~80 of a
    full Threefry evaluation, with BigCrush-level stream quality."""
    z = jnp.asarray(key64, jnp.uint64) + (
        ctr.astype(jnp.uint64) + jnp.uint64(1)
    ) * jnp.uint64(_SM_GAMMA)
    z = (z ^ (z >> 30)) * jnp.uint64(_SM_MIX1)
    z = (z ^ (z >> 27)) * jnp.uint64(_SM_MIX2)
    z = z ^ (z >> 31)
    return (z >> 32).astype(jnp.uint32), z.astype(jnp.uint32)


# repro-lint: jit-root
def stream_key(k0, k1):
    """Pack a Threefry subkey pair into the per-draw key representation:
    a single uint64 (SplitMix64 draws) when 64-bit integers are enabled
    — the CPU/GPU x64 path, matching :meth:`TraceSpec.materialize` — or
    the uint32 pair itself (Threefry draws) on x32/TPU, where uint64 is
    unavailable."""
    if jnp.zeros((), jnp.uint64).dtype == np.dtype("uint64"):
        return ((k0.astype(jnp.uint64) << 32) | k1.astype(jnp.uint64),)
    return (k0, k1)


# repro-lint: jit-root
def counter_words(key, ctr):
    """Output words of draw ``ctr`` for a :func:`stream_key` key."""
    if len(key) == 1:
        return splitmix64(key[0], ctr)
    return threefry2x32(key[0], key[1], ctr.astype(jnp.uint32), jnp.uint32(0))


# repro-lint: jit-root
def counter_uniform(key, ctr, dtype):
    """Draw ``ctr``'s uniform from the stream keyed ``key``."""
    x0, _ = counter_words(key, ctr)
    return uniform24(x0, dtype)


# repro-lint: jit-root
def counter_uniform2(key, ctr, dtype):
    """Both uniforms of one draw (e.g. the TP coin stream: word 0 is the
    predicted coin, word 1 the window-offset fraction)."""
    x0, x1 = counter_words(key, ctr)
    return uniform24(x0, dtype), uniform24(x1, dtype)


# repro-twin: repro.core.events.gap_transform_np
# repro-lint: jit-root
def gap_transform(kind: str, param: float, mean, x0, x1, dtype):
    """Inverse-CDF inter-arrival transform of one counter draw (jnp twin
    of ``core.events.gap_transform_np``; ``kind`` is compile-time static).
    Only the lognormal family consumes the second cipher word (Box–Muller
    phase).  Clamped to the host generator's ``1e-9`` zero-gap guard."""
    u = uniform24(x0, dtype)
    if kind == "exponential":
        g = -jnp.log1p(-u) * mean
    elif kind == "weibull":
        scale = 1.0 / _gamma(1.0 + 1.0 / param)
        g = (mean * scale) * (-jnp.log1p(-u)) ** (1.0 / param)
    elif kind == "lognormal":
        z = jnp.sqrt(-2.0 * jnp.log(u)) * jnp.cos(
            jnp.asarray(2.0 * 3.141592653589793, dtype) * uniform24(x1, dtype)
        )
        g = jnp.exp(jnp.log(mean) - 0.5 * param * param + param * z)
    elif kind == "uniform":
        g = 2.0 * mean * u
    else:  # pragma: no cover - validated at TraceSpec construction
        raise ValueError(f"unsupported gap kind {kind!r}")
    return jnp.maximum(g, 1e-9)


# repro-twin: repro.core.events.gap_transform_indexed_np
# repro-lint: jit-root
def gap_transform_indexed(law, s1, s2, mean, x0, x1, dtype):
    """Law-multiplexed inverse-CDF transform: the branchless select twin
    of :func:`gap_transform` for mixed-law cell tables.

    ``law`` is the per-lane int32 law index (``core.events.LAW_*``) and
    ``(s1, s2)`` the pre-folded shape slots of the unified 4-slot
    parameter row (``core.events.law_table``): Weibull ``s1 = 1/Γ(1+1/k)``,
    ``s2 = 1/k``; lognormal ``s1 = σ``, ``s2 = σ²/2``.  Every family's
    expression is evaluated (a pure VPU elementwise pass) and one
    ``where`` chain selects per lane; each branch is written so that with
    the slots pinned to a single family it folds to the *same* XLA ops as
    the compile-time-specialized path — the per-cell bit-identity the
    fused mixed-law dispatch is gated on."""
    u = uniform24(x0, dtype)
    nlog = -jnp.log1p(-u)
    g_exp = nlog * mean
    # mirror the compiler's static-exponent pow strength reductions
    # (x ** 2.0 -> x * x, x ** 0.5 -> sqrt) so the data-driven exponent
    # reproduces the specialized path's bits for those shapes too
    p = nlog ** s2
    p = jnp.where(s2 == 2.0, nlog * nlog, p)
    p = jnp.where(s2 == 0.5, jnp.sqrt(nlog), p)
    g_wei = (mean * s1) * p
    z = jnp.sqrt(-2.0 * jnp.log(u)) * jnp.cos(
        jnp.asarray(2.0 * 3.141592653589793, dtype) * uniform24(x1, dtype)
    )
    g_log = jnp.exp(jnp.log(mean) - s2 + s1 * z)
    g_uni = 2.0 * mean * u
    g = jnp.where(
        law == LAW_WEIBULL, g_wei,
        jnp.where(
            law == LAW_LOGNORMAL, g_log,
            jnp.where(law == LAW_UNIFORM, g_uni, g_exp),
        ),
    )
    return jnp.maximum(g, 1e-9)


# repro-lint: jit-root
def stream_advance(
    mask, ctr, tm, key, mean, horizon, *, kind, param, law=None, lp=None,
):
    """Advance a renewal-stream cursor by one event where ``mask``.

    Draws gap ``ctr + 1`` from the counter stream, accumulates the event
    date, and retires the stream (``+inf``) once it crosses the lane's
    generation horizon — the O(1)-state replacement for a materialized,
    sentinel-padded event row.  Lanes outside ``mask`` are untouched, and
    a draw is a pure function of ``(key, counter)``, so cursor replays
    (e.g. the strike cursor re-walking the lookahead cursor's fault
    stream) observe bit-identical dates.

    ``kind="indexed"`` selects the law-multiplexed transform: ``law`` is
    the per-lane int32 law index and ``lp`` the ``(s1, s2)`` shape-slot
    pair (``param`` is ignored)."""
    c2 = ctr + 1
    x0, x1 = counter_words(key, c2)
    if kind == "indexed":
        g = gap_transform_indexed(law, lp[0], lp[1], mean, x0, x1, tm.dtype)
    else:
        g = gap_transform(kind, param, mean, x0, x1, tm.dtype)
    t2 = tm + g
    t2 = jnp.where(t2 > horizon, jnp.asarray(jnp.inf, tm.dtype), t2)
    return jnp.where(mask, c2, ctr), jnp.where(mask, t2, tm)


# --------------------------------------------------------------------------- #
# Cell multiplexing (fused experiment sweeps)
# --------------------------------------------------------------------------- #
# repro-lint: jit-root
def cell_gather(consts: dict, cidx, keys) -> dict:
    """Broadcast per-cell table rows to per-lane arrays.

    The fused sweep ships each engine parameter as a compact ``(n_cells,)``
    table plus one ``(lanes,)`` int32 ``cidx``; this gather — one fused
    ``take`` per parameter at the top of the jitted program — recovers the
    per-lane layout the lane machine runs on, so lanes from many
    experiment cells interleave freely across chunks and shards.  Returns
    a copy of ``consts`` with every key in ``keys`` gathered (keys absent
    from ``consts`` are skipped: trace-mode-specific tables)."""
    out = dict(consts)
    for k in keys:
        # keys is a static tuple of table names, not traced data
        if k in consts:  # repro-lint: disable=tracer-branch
            out[k] = jnp.take(consts[k], cidx, axis=0)
    return out


# repro-lint: jit-root
def segment_cell_sums(values, cidx, num_cells: int):
    """Per-cell sums of per-lane columns in one segment reduction.

    ``values`` is a sequence of ``(L,)`` arrays (clock, waste, event
    counters, ...); the result is a ``(num_cells, len(values))`` float
    matrix whose row ``c`` sums the lanes with ``cidx == c`` — the
    device-side reduction of per-cell Monte-Carlo moments, so a fused
    sweep can fetch O(cells) statistics instead of O(lanes) results.
    Counters are exact in f64 (and up to 2^24 lanes in the f32/TPU
    path); callers route padding lanes to a sacrificial trailing cell
    row and drop it host-side."""
    import jax

    fdt = values[0].dtype
    x = jnp.stack([v.astype(fdt) for v in values], axis=-1)
    return jax.ops.segment_sum(x, cidx, num_segments=num_cells)


def _advance_kernel(*refs, kind: str, param: float, nkey: int):
    mask_ref, ctr_ref, tm_ref = refs[:3]
    key = tuple(r[...] for r in refs[3:3 + nkey])
    if kind == "indexed":
        (mean_ref, horizon_ref, law_ref, s1_ref, s2_ref,
         ctr_out, tm_out) = refs[3 + nkey:]
        law, lp = law_ref[...], (s1_ref[...], s2_ref[...])
    else:
        mean_ref, horizon_ref, ctr_out, tm_out = refs[3 + nkey:]
        law = lp = None
    ctr, tm = stream_advance(
        mask_ref[...] != 0, ctr_ref[...], tm_ref[...], key,
        mean_ref[...], horizon_ref[...], kind=kind, param=param,
        law=law, lp=lp,
    )
    ctr_out[...] = ctr
    tm_out[...] = tm


def masked_stream_advance(
    mask, ctr, tm, key, mean, horizon, *, kind: str, param: float,
    law=None, lp=None, interpret: bool | None = None, tile: int = 8,
):
    """Pallas entry of :func:`stream_advance` over flat ``(L,)`` lanes
    (L % 128 == 0), same layout/tiling contract as
    :func:`masked_primitive_update`; the kernel body *is* the jnp
    function, so both paths are bit-identical.  ``kind="indexed"`` ships
    the per-lane ``law`` index and ``lp = (s1, s2)`` slot arrays as three
    extra kernel inputs."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    L = tm.shape[0]
    if L % 128:
        raise ValueError(f"lane count {L} not a multiple of 128")
    rows = L // 128
    if interpret:
        tile = rows
    tile = max(1, min(tile, rows))
    while rows % tile:
        tile //= 2
    fdt = tm.dtype

    def as2d(x, dtype=None):
        # dtype=None deliberately preserves the key words' uint dtype
        x = jnp.asarray(x) if dtype is None else jnp.asarray(x, dtype)  # repro-lint: disable=kernel-dtype
        return x.reshape(rows, 128)

    ins = [
        as2d(mask, jnp.int32),
        as2d(ctr, jnp.int32),
        as2d(tm, fdt),
        *[as2d(k) for k in key],
        as2d(mean, fdt),
        as2d(horizon, fdt),
    ]
    if kind == "indexed":
        ins += [as2d(law, jnp.int32), as2d(lp[0], fdt), as2d(lp[1], fdt)]
    spec = pl.BlockSpec((tile, 128), lambda i: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct((rows, 128), jnp.int32),
        jax.ShapeDtypeStruct((rows, 128), fdt),
    ]
    outs = pl.pallas_call(
        partial(_advance_kernel, kind=kind, param=param, nkey=len(key)),
        grid=(rows // tile,),
        in_specs=[spec] * len(ins),
        out_specs=[spec] * len(out_shape),
        out_shape=out_shape,
        # the cursor pair is loop-carried state: update it in place
        input_output_aliases={1: 0, 2: 1},
        interpret=interpret,
    )(*ins)
    return tuple(o.reshape(L) for o in outs)


def _step_kernel(
    prim_ref, cont_ref, target_ref, ckend_ref, nf_ref,
    t_ref, saved_ref, unsaved_ref, pw_ref, w_ref, dr_ref,
    t_out, saved_out, unsaved_out, pw_out, flags_out,
    *, eps: float, reg_cont: int,
):
    t, saved, unsaved, pw, flags = primitive_update(
        prim_ref[...], cont_ref[...], target_ref[...],
        ckend_ref[...], nf_ref[...], t_ref[...], saved_ref[...],
        unsaved_ref[...], pw_ref[...], w_ref[...], dr_ref[...],
        eps=eps, reg_cont=reg_cont,
    )
    t_out[...] = t
    saved_out[...] = saved
    unsaved_out[...] = unsaved
    pw_out[...] = pw
    flags_out[...] = flags


def _step_gen_kernel(*refs, eps: float, reg_cont: int, gap, nkey: int):
    # device trace mode: the strike time IS nf, so the stream tuple
    # reuses nf_ref and the consumed fault is refilled in-kernel
    (prim_ref, cont_ref, target_ref, ckend_ref, nf_ref,
     t_ref, saved_ref, unsaved_ref, pw_ref, w_ref, dr_ref) = refs[:11]
    key = tuple(r[...] for r in refs[11:11 + nkey])
    sctr_ref, mean_ref, horizon_ref = refs[11 + nkey:14 + nkey]
    stream = (key, sctr_ref[...], nf_ref[...],
              mean_ref[...], horizon_ref[...])
    if gap[0] == "indexed":  # + per-lane law index and (s1, s2) slots
        law_ref, s1_ref, s2_ref = refs[14 + nkey:17 + nkey]
        stream = stream + (law_ref[...], s1_ref[...], s2_ref[...])
        rest = refs[17 + nkey:]
    else:
        rest = refs[14 + nkey:]
    (t_out, saved_out, unsaved_out, pw_out, flags_out,
     sctr_out, stm_out) = rest
    t, saved, unsaved, pw, flags, sctr, stm = primitive_update(
        prim_ref[...], cont_ref[...], target_ref[...],
        ckend_ref[...], nf_ref[...], t_ref[...], saved_ref[...],
        unsaved_ref[...], pw_ref[...], w_ref[...], dr_ref[...],
        eps=eps, reg_cont=reg_cont, stream=stream, gap=gap,
    )
    t_out[...] = t
    saved_out[...] = saved
    unsaved_out[...] = unsaved
    pw_out[...] = pw
    flags_out[...] = flags
    sctr_out[...] = sctr
    stm_out[...] = stm


def masked_primitive_update(
    prim, cont, target, ckend, nf, t, saved, unsaved, pw, W, DR,
    *, eps: float, reg_cont: int, interpret: bool | None = None,
    tile: int = 8, stream=None, gap=None,
):
    """Pallas entry point over flat ``(L,)`` lane vectors, L % 128 == 0.

    The lane axis is viewed as ``(L // 128, 128)`` and tiled ``tile`` rows
    per grid step (8 rows = the f32 sublane tile).  ``interpret`` defaults
    to True off-TPU (the repo-wide kernel idiom, see kernels/ops.py).

    With ``stream``/``gap`` (device trace mode; ``stream[3]`` must be the
    same array as ``nf``) the sampling step is fused into the kernel and
    the advanced strike cursor is appended to the outputs.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    L = t.shape[0]
    if L % 128:
        raise ValueError(f"lane count {L} not a multiple of 128")
    rows = L // 128
    if interpret:
        tile = rows  # no VMEM budget to respect: one grid step, no slicing
    tile = max(1, min(tile, rows))
    while rows % tile:
        tile //= 2

    fdt = t.dtype

    def as2d(x, dtype):
        return jnp.asarray(x, dtype).reshape(rows, 128)

    ins = [
        as2d(prim, jnp.int32),
        as2d(cont, jnp.int32),
        as2d(target, fdt),
        as2d(ckend, fdt),
        as2d(nf, fdt),
        as2d(t, fdt),
        as2d(saved, fdt),
        as2d(unsaved, fdt),
        as2d(pw, fdt),
        as2d(W, fdt),
        as2d(DR, fdt),
    ]
    spec = pl.BlockSpec((tile, 128), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((rows, 128), fdt)] * 4 + [
        jax.ShapeDtypeStruct((rows, 128), jnp.int32)
    ]
    # the float lane-state slabs (t/saved/unsaved/pw, inputs 5-8) are
    # loop-carried intermediates: alias them onto the corresponding
    # outputs so the step updates state in place instead of streaming
    # four fresh (rows, 128) buffers per iteration
    aliases = {5: 0, 6: 1, 7: 2, 8: 3}
    if stream is None:
        kernel = partial(_step_kernel, eps=eps, reg_cont=reg_cont)
    else:
        skey, sctr, _, smean, shorizon = stream[:5]
        ins += [
            # dtype-preserving on purpose: uint64 (SplitMix) or uint32 pair
            *[jnp.asarray(k).reshape(rows, 128) for k in skey],  # repro-lint: disable=kernel-dtype
            as2d(sctr, jnp.int32),
            as2d(smean, fdt),
            as2d(shorizon, fdt),
        ]
        if len(stream) == 8:  # law-multiplexed: law index + (s1, s2)
            ins += [
                as2d(stream[5], jnp.int32),
                as2d(stream[6], fdt),
                as2d(stream[7], fdt),
            ]
        out_shape += [
            jax.ShapeDtypeStruct((rows, 128), jnp.int32),
            jax.ShapeDtypeStruct((rows, 128), fdt),
        ]
        aliases[11 + len(skey)] = 5  # the strike counter is loop-carried
        kernel = partial(
            _step_gen_kernel, eps=eps, reg_cont=reg_cont, gap=gap,
            nkey=len(skey),
        )
    outs = pl.pallas_call(
        kernel,
        grid=(rows // tile,),
        in_specs=[spec] * len(ins),
        out_specs=[spec] * len(out_shape),
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(*ins)
    return tuple(o.reshape(L) for o in outs)
