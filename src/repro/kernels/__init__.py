"""Pallas TPU kernels for the perf-critical compute layers.

<name>.py    pl.pallas_call + BlockSpec VMEM tiling (TPU target)
ops.py       jit'd wrappers (layout + GQA handling + interpret fallback)
ref.py       pure-jnp oracles the kernels are validated against
sim_step.py  masked primitive-update step of the device simulation engine
"""
