"""WKV6 recurrence kernel (RWKV-6 "Finch" time mix) — Pallas, TPU.

The recurrence per head (state S in R^{hd_k x hd_v}):

    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

Data-dependent per-channel decays ``w_t`` make the textbook chunked-matmul
factorization numerically unsafe (exp(-sum log w) overflows for
fast-decay channels), so the TPU design keeps the *state resident in VMEM
scratch* across a sequential chunk grid and streams (chunk x hd) r/k/v/w
tiles HBM->VMEM per step; inside a chunk an exact fori loop performs the
per-token rank-1 updates on VREGs.  This is bandwidth-optimal (each input
element is read once; the O(hd^2) state never leaves VMEM) — the right
target for a memory-bound linear-recurrence layer — while remaining exact.

Layout: (BH, S, hd) inputs; state (BH, hd, hd); grid (BH, S / chunk).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["wkv6_bhsd"]


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref, s_scr, *, chunk):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)  # (chunk, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)  # (1, hd) — keep 2-D so u.T is (hd, 1)

    def step(t, carry):
        s, y = carry
        kt = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)  # (1, hd)
        vt = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)
        rt = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)
        wt = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)
        kv = kt.T @ vt  # (hd_k, hd_v) rank-1
        yt = rt @ (s + u.T * kv)  # (1, hd_v)
        s = wt.T * s + kv
        y = jax.lax.dynamic_update_slice_in_dim(y, yt, t, 0)
        return s, y

    s, y = jax.lax.fori_loop(
        0, chunk, step, (s_scr[...], jnp.zeros_like(r))
    )
    s_scr[...] = s
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _out():
        sT_ref[0] = s_scr[...].astype(sT_ref.dtype)


def wkv6_bhsd(
    r: jax.Array,  # (BH, S, hd)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # decays in (0, 1)
    u: jax.Array,  # (BH, hd) bonus
    s0: jax.Array,  # (BH, hd, hd) initial state
    *,
    chunk: int = 64,
    interpret: bool = False,
):
    """Returns (y (BH,S,hd), final state (BH,hd,hd))."""
    BH, S, hd = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    grid = (BH, S // chunk)
    from jax.experimental.pallas import tpu as pltpu

    kern = functools.partial(_wkv_kernel, chunk=chunk)
    y, sT = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, hd), lambda b, ci: (b, 0)),
            pl.BlockSpec((1, hd, hd), lambda b, ci: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, hd, hd), lambda b, ci: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, hd), r.dtype),
            jax.ShapeDtypeStruct((BH, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, sT
