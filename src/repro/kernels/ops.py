"""Jit'd public wrappers around the Pallas kernels.

Model-facing layouts in, kernel layouts out:
* GQA broadcast (KV heads -> query heads) happens here, so the kernels see
  plain MHA (BH, S, hd);
* on non-TPU backends the kernels run in interpret mode (exact semantics,
  Python-speed — used by the test suite); the TPU runtime compiles the
  real Mosaic kernels.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref as ref_mod
from .ckpt_codec import dequantize_blocks, quantize_blocks
from .decode_attention import decode_attention_bhd
from .flash_attention import flash_attention_bhsd
from .rwkv6 import wkv6_bhsd

__all__ = [
    "flash_attention",
    "decode_attention",
    "wkv6",
    "quantize_checkpoint",
    "dequantize_checkpoint",
]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, blk_q: int = 512, blk_k: int = 512):
    """q,k,v: (B, S, H, hd) with identical head counts (GQA pre-broadcast
    by the caller — models/layers.py does this)."""
    B, S, H, hd = q.shape
    qf = jnp.moveaxis(q, 2, 1).reshape(B * H, S, hd)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * H, k.shape[1], hd)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * H, v.shape[1], hd)
    of = flash_attention_bhsd(
        qf, kf, vf, causal=causal, blk_q=blk_q, blk_k=blk_k, interpret=_interpret()
    )
    return jnp.moveaxis(of.reshape(B, H, S, hd), 1, 2)


def decode_attention(q, k, v, pos, *, blk_k: int = 512):
    """q: (B, 1, H, hd); k,v caches: (B, S_max, H, hd) (GQA pre-broadcast)."""
    B, _, H, hd = q.shape
    S = k.shape[1]
    qf = q[:, 0].reshape(B * H, hd)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * H, S, hd)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * H, S, hd)
    of = decode_attention_bhd(qf, kf, vf, pos, blk_k=blk_k, interpret=_interpret())
    return of.reshape(B, 1, H, hd)


def wkv6(r, k, v, w, u, s0, *, chunk: int = 64):
    """r,k,v,w: (B, S, H, hd); u: (H, hd); s0: (B, H, hd, hd)."""
    B, S, H, hd = r.shape

    def flat(x):
        return jnp.moveaxis(x, 2, 1).reshape(B * H, S, hd)

    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, hd)
    s0f = s0.reshape(B * H, hd, hd)
    yf, sTf = wkv6_bhsd(
        flat(r), flat(k), flat(v), flat(w), uf, s0f, chunk=chunk,
        interpret=_interpret(),
    )
    y = jnp.moveaxis(yf.reshape(B, H, S, hd), 1, 2)
    return y, sTf.reshape(B, H, hd, hd)


def quantize_checkpoint(x, prev=None, *, tile: int = 512):
    """Flat f32 array -> (int8 blocks, scales, original size)."""
    n = x.size
    pad = (-n) % 256
    flat = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad)).reshape(-1, 256)
    p = None
    if prev is not None:
        p = jnp.pad(prev.reshape(-1).astype(jnp.float32), (0, pad)).reshape(-1, 256)
    nb = flat.shape[0]
    t = tile
    while nb % t:
        t //= 2
    q, s = quantize_blocks(flat, p, tile=max(t, 1), interpret=_interpret())
    return q, s, n


def dequantize_checkpoint(q, s, n, shape, prev=None, *, tile: int = 512):
    p = None
    if prev is not None:
        pad = (-prev.size) % 256
        p = jnp.pad(prev.reshape(-1).astype(jnp.float32), (0, pad)).reshape(-1, 256)
    nb = q.shape[0]
    t = tile
    while nb % t:
        t //= 2
    x = dequantize_blocks(q, s, p, tile=max(t, 1), interpret=_interpret())
    return x.reshape(-1)[:n].reshape(shape)
