"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Each kernel test sweeps shapes/dtypes and asserts allclose against these.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = [
    "flash_attention_ref",
    "decode_attention_ref",
    "wkv6_ref",
    "quantize_ref",
    "dequantize_ref",
]


def flash_attention_ref(q, k, v, causal: bool = True):
    """q,k,v: (BH, S, hd)."""
    hd = q.shape[-1]
    s = jnp.einsum(
        "bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(hd)
    if causal:
        S, T = s.shape[-2], s.shape[-1]
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, pos):
    """q: (BH, hd); k,v: (BH, S, hd); pos: scalar newest valid index."""
    hd = q.shape[-1]
    s = jnp.einsum(
        "bd,bkd->bk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(hd)
    valid = jnp.arange(k.shape[1])[None, :] <= pos
    s = jnp.where(valid, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bk,bkd->bd", w, v.astype(jnp.float32)).astype(q.dtype)


def wkv6_ref(r, k, v, w, u, s0):
    """Exact per-token WKV6.  r,k,v,w: (BH,S,hd); u: (BH,hd); s0: (BH,hd,hd)."""
    rt = jnp.moveaxis(r.astype(jnp.float32), 1, 0)
    kt = jnp.moveaxis(k.astype(jnp.float32), 1, 0)
    vt = jnp.moveaxis(v.astype(jnp.float32), 1, 0)
    wt = jnp.moveaxis(w.astype(jnp.float32), 1, 0)
    u = u.astype(jnp.float32)

    def step(s, inp):
        ri, ki, vi, wi = inp
        kv = ki[:, :, None] * vi[:, None, :]
        y = jnp.einsum("bi,bij->bj", ri, s + u[:, :, None] * kv)
        s = wi[:, :, None] * s + kv
        return s, y

    sT, ys = jax.lax.scan(step, s0.astype(jnp.float32), (rt, kt, vt, wt))
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), sT


def quantize_ref(x, prev=None):
    """x: (n_blocks, 256) f32 -> (int8, scales (n_blocks,1))."""
    base = x.astype(jnp.float32)
    if prev is not None:
        base = base - prev.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(base), axis=1, keepdims=True) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(base / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_ref(q, s, prev=None):
    x = q.astype(jnp.float32) * s
    if prev is not None:
        x = x + prev.astype(jnp.float32)
    return x
