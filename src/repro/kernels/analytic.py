"""jnp twins of the analytic waste layer + the batched period optimizer.

Every function up to :func:`cell_waste` is the jnp twin of its namesake
in :mod:`repro.core.analytic` (registered in
``analysis.twins.TWIN_REGISTRY``; edit both sides together).  They are
branchless, vmappable over the fused engine's per-cell parameter
columns, and — the point of the jnp dialect — differentiable, so the
optimizer below runs :func:`jax.grad` / second derivatives through the
paper's waste formulas instead of scanning period grids.

:func:`newton_policy` solves every cell's optimal regular period in one
jitted dispatch: per-cell safeguarded Newton steps (accepted only when
the local second derivative is positive and the step stays inside a
shrinking derivative-sign bracket, else bisection) on the domain
``[lo, hi]`` supplied by the host, split at ``T = I`` where strategy
Instant's waste is non-smooth (``min(E_f, T/2)``) — each sub-interval
is convex, so bracketed Newton on both and a final compare is the
global minimizer.  The q in {0, q_eff} case analysis of the host
``optimize_*`` functions runs vectorized on top.

The module stays dtype-polymorphic (kernel discipline: the caller picks
x64/x32 via the enable-x64 context; nothing here names a wide dtype).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..core.waste import i_prime

__all__ = [
    "precision_from_fp",
    "young_waste",
    "exact_waste",
    "migration_waste",
    "instant_waste",
    "nockpt_waste",
    "withckpt_waste",
    "two_level_waste",
    "silent_waste",
    "cell_waste",
    "newton_policy",
]

#: integer strategy-mode codes of the engine tables (values of
#: ``repro.core.batch_sim.MODE_CODES``, fixed by the packing format)
_M_NONE, _M_EXACT, _M_NOCKPT, _M_WITHCKPT, _M_MIGRATION = 0, 1, 2, 3, 4
_M_TWO_LEVEL, _M_SILENT = 5, 6


# --------------------------------------------------------------------------- #
# Twin waste models (keep in lockstep with repro.core.analytic)
# --------------------------------------------------------------------------- #
# repro-twin: repro.core.analytic.precision_from_fp
def precision_from_fp(mu, fp_mean, r):
    fin = jnp.isfinite(fp_mean)
    fp = jnp.where(fin, fp_mean, 1.0)
    return jnp.where(fin, r * fp / (mu + r * fp), 1.0)


# repro-twin: repro.core.analytic.young_waste
def young_waste(T, C, DR, mu):
    return C / T + (T / 2.0 + DR) / mu


# repro-twin: repro.core.analytic.exact_waste
def exact_waste(T, q, C, DR, mu, r, p):
    p_safe = jnp.where(r > 0.0, p, 1.0)
    pred_term = jnp.where(r > 0.0, (q * r / p_safe) * C, 0.0)
    return C / T + ((1.0 - r * q) * T / 2.0 + DR + pred_term) / mu


# repro-twin: repro.core.analytic.migration_waste
def migration_waste(T, q, C, DR, M, mu, r, p):
    p_safe = jnp.where(r > 0.0, p, 1.0)
    pred_term = jnp.where(r > 0.0, (q * r / p_safe) * M, 0.0)
    return C / T + ((1.0 - r * q) * (T / 2.0 + DR) + pred_term) / mu


# repro-twin: repro.core.analytic.instant_waste
def instant_waste(T, q, C, DR, mu, r, p, E_f):
    p_safe = jnp.where(r > 0.0, p, 1.0)
    pred_term = jnp.where(r > 0.0, (q * r / p_safe) * C, 0.0)
    lost = q * r * jnp.minimum(E_f, T / 2.0)
    return C / T + ((1.0 - r * q) * T / 2.0 + DR + pred_term + lost) / mu


# repro-twin: repro.core.analytic.nockpt_waste
def nockpt_waste(T, q, C, DR, mu, r, p, I, E_f):
    r_safe = jnp.where(r > 0.0, r, 0.5)
    p_safe = jnp.where(r > 0.0, p, 1.0)
    m_p = p_safe * mu / r_safe
    m_np = mu / (1.0 - r_safe)
    ip = jnp.minimum(i_prime(q, p_safe, I, E_f), m_p)
    reg_frac = 1.0 - ip / m_p
    w = (reg_frac / T + q / m_p) * C
    w = w + (p_safe * (1.0 - q) / m_p) * (T / 2.0)
    w = w + (p_safe * q / m_p) * E_f
    w = w + reg_frac / m_np * (T / 2.0)
    w = w + (p_safe / m_p + reg_frac / m_np) * DR
    return jnp.where(r > 0.0, w, young_waste(T, C, DR, mu))


# repro-twin: repro.core.analytic.withckpt_waste
def withckpt_waste(T, T_P, q, C, DR, mu, r, p, I, E_f):
    r_safe = jnp.where(r > 0.0, r, 0.5)
    p_safe = jnp.where(r > 0.0, p, 1.0)
    m_p = p_safe * mu / r_safe
    m_np = mu / (1.0 - r_safe)
    ip = jnp.minimum(i_prime(q, p_safe, I, E_f), m_p)
    reg_frac = 1.0 - ip / m_p
    w = (reg_frac / T + (ip / m_p) / T_P + q / m_p) * C
    w = w + (p_safe * (1.0 - q) / m_p) * (T / 2.0)
    w = w + (p_safe * q / m_p) * T_P
    w = w + reg_frac / m_np * (T / 2.0)
    w = w + (p_safe / m_p + reg_frac / m_np) * DR
    return jnp.where(r > 0.0, w, young_waste(T, C, DR, mu))


# repro-twin: repro.core.analytic.two_level_waste
def two_level_waste(T_m, T_d, C_m, C_d, D, R_m, R_d, mu, f, r, q, p):
    w = C_m / T_m + C_d / T_d
    w = w + (
        f * ((1.0 - r * q) * T_m / 2.0 + D + R_m)
        + (1.0 - f) * (T_d / 2.0 + D + R_d)
    ) / mu
    p_safe = jnp.where(r > 0.0, p, 1.0)
    pred = jnp.where((r > 0.0) & (q > 0.0), (q * r / p_safe) * C_m / mu, 0.0)
    return w + pred


# repro-twin: repro.core.analytic.silent_waste
def silent_waste(T, C, V, DR, mu, k):
    return (k * C + V) / (k * T) + (k * T + V + DR) / mu


# repro-twin: repro.core.analytic.cell_waste
def cell_waste(
    T, mode, q, C, DR, lead_act, mu, r, p, window, T_P, tp_eff,
    C2, DR2, V, fmem, rho, kv,
):
    E_f = 0.5 * window
    tp = jnp.where(jnp.isnan(T_P), tp_eff, T_P)
    w_y = young_waste(T, C, DR, mu)
    w = jnp.where(
        window > 0.0,
        instant_waste(T, q, C, DR, mu, r, p, E_f),
        exact_waste(T, q, C, DR, mu, r, p),
    )
    w = jnp.where(
        mode == _M_MIGRATION, migration_waste(T, q, C, DR, lead_act, mu, r, p), w
    )
    w = jnp.where(
        mode == _M_NOCKPT, nockpt_waste(T, q, C, DR, mu, r, p, window, E_f), w
    )
    w = jnp.where(
        mode == _M_WITHCKPT,
        withckpt_waste(T, tp, q, C, DR, mu, r, p, window, E_f),
        w,
    )
    w = jnp.where((mode == _M_NONE) | (q <= 0.0) | (r <= 0.0), w_y, w)
    w = jnp.where(
        mode == _M_TWO_LEVEL,
        two_level_waste(T, rho * T, C, C2, 0.0, DR, DR2, mu, fmem, r, q, p),
        w,
    )
    return jnp.where(mode == _M_SILENT, silent_waste(T, C, V, DR, mu, kv), w)


# --------------------------------------------------------------------------- #
# Batched safeguarded-Newton period optimization
# --------------------------------------------------------------------------- #
#: per-cell objective and its first/second T-derivatives, vmapped over
#: every column (the differentiability the jnp dialect buys)
_N_ARGS = 18
_waste_v = jax.vmap(cell_waste, in_axes=(0,) * _N_ARGS)
_grad_v = jax.vmap(jax.grad(cell_waste), in_axes=(0,) * _N_ARGS)
_hess_v = jax.vmap(jax.grad(jax.grad(cell_waste)), in_axes=(0,) * _N_ARGS)


def _solve_bracket(cols, T0, lo, hi, iters):
    """Safeguarded Newton on one convex sub-interval, all cells at once.

    Maintains a bracket on the derivative's sign change: W' <= 0 moves
    ``lo``, W' > 0 moves ``hi`` (convexity makes the minimizer the
    unique sign change, or a boundary — which the bracket collapses
    onto).  A Newton step ``T - W'/W''`` is taken when the curvature is
    positive, finite and the step stays strictly inside the bracket;
    otherwise the iteration bisects.  ``iters`` bisections bound the
    error by ``(hi - lo) * 2**-iters`` even if Newton never fires."""

    def body(_, st):
        T, lo_b, hi_b = st
        g = _grad_v(T, *cols)
        h = _hess_v(T, *cols)
        lo_b = jnp.where(g <= 0.0, T, lo_b)
        hi_b = jnp.where(g > 0.0, T, hi_b)
        Tn = T - g / jnp.where(h > 0.0, h, 1.0)
        ok = (h > 0.0) & jnp.isfinite(Tn) & (Tn > lo_b) & (Tn < hi_b)
        T = jnp.where(ok, Tn, 0.5 * (lo_b + hi_b))
        return (T, lo_b, hi_b)

    T, _, _ = lax.fori_loop(0, iters, body, (jnp.clip(T0, lo, hi), lo, hi))
    return T


# repro-lint: jit-root
@partial(jax.jit, static_argnames="iters")
def newton_policy(
    mode, q, C, DR, lead_act, mu, r, p, window, T_P, tp_eff,
    C2, DR2, V, fmem, rho, kv,
    lo, hi0, hi1, iters: int = 60,
):
    """One-dispatch batched period optimization over a cell table.

    Solves the trusted branch (q as tabled) on ``[lo, hi1]`` — split at
    the Instant kink ``T = window`` — and the untrusted q = 0 branch on
    ``[lo, hi0]``, then keeps the better operating point per cell (the
    waste is affine in q, so the optimum is at q = 0 or q = q_eff,
    mirroring the host case analyses).  The two-level / silent-error
    columns (``C2``/``DR2``/``V``/``fmem``/``rho``/``kv``) are benign
    fills (0/0/0/0/1/1) on every other mode's cells.  Returns
    ``(T, q, waste, T0, waste0, T1, waste1)`` with ``waste`` min'd
    against 1 like :class:`~repro.core.periods.OptimalPolicy`."""
    extra = (C2, DR2, V, fmem, rho, kv)
    cols1 = (mode, q, C, DR, lead_act, mu, r, p, window, T_P, tp_eff) + extra
    zq = jnp.zeros_like(q)
    cols0 = (mode, zq, C, DR, lead_act, mu, r, p, window, T_P, tp_eff) + extra

    t0_guess = jnp.sqrt(2.0 * mu * C)
    den = jnp.maximum(1.0 - r * q, 0.015625)
    t1_guess = jnp.sqrt(2.0 * mu * C / den)

    knot = jnp.clip(window, lo, hi1)
    Ta = _solve_bracket(cols1, jnp.minimum(t0_guess, knot), lo, knot, iters)
    Tb = _solve_bracket(cols1, jnp.maximum(t1_guess, knot), knot, hi1, iters)
    wa = _waste_v(Ta, *cols1)
    wb = _waste_v(Tb, *cols1)
    T1 = jnp.where(wa <= wb, Ta, Tb)
    w1 = jnp.minimum(wa, wb)

    T0 = _solve_bracket(cols0, t0_guess, lo, hi0, iters)
    w0 = _waste_v(T0, *cols0)

    use1 = (w1 < w0) & (q > 0.0) & (r > 0.0)
    T = jnp.where(use1, T1, T0)
    qs = jnp.where(use1, q, 0.0)
    w = jnp.where(use1, w1, w0)
    return T, qs, jnp.minimum(w, 1.0), T0, w0, T1, w1
