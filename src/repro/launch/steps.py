"""Step builders + abstract input specs shared by dryrun/train/serve.

``input_specs(arch, shape)`` provides ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, no device allocation) — the
pattern the assignment prescribes for the multi-pod dry-run.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from ..configs.base import ArchConfig, ShapeConfig
from ..models.layers import RuntimeFlags
from ..models.transformer import LanguageModel
from ..optim.adamw import AdamWState, adamw_init, adamw_update, cosine_schedule
from ..parallel.sharding import ShardingRules, make_rules

__all__ = [
    "build_model",
    "build_train_step",
    "build_prefill_step",
    "build_decode_step",
    "input_specs",
    "train_arg_structs",
    "decode_arg_structs",
    "prefill_arg_structs",
    "fitted_sharding",
    "tree_shardings",
    "zero1_moment_specs",
]


# --------------------------------------------------------------------------- #
# sharding helpers
# --------------------------------------------------------------------------- #
def _axes_size(mesh, assignment) -> int:
    if assignment is None:
        return 1
    if isinstance(assignment, str):
        return mesh.shape[assignment]
    return math.prod(mesh.shape[a] for a in assignment)


def fitted_sharding(
    struct: jax.ShapeDtypeStruct, logical, rules: ShardingRules
) -> NamedSharding:
    """NamedSharding from logical axes, dropping any axis that does not
    divide the dimension (e.g. batch=1 long_500k on a 16-wide data axis)."""
    mesh = rules.mesh
    assert mesh is not None
    spec = []
    for dim, logical_name in zip(struct.shape, tuple(logical) + (None,) * 10):
        a = rules.assignment(logical_name)
        if a is not None and dim % _axes_size(mesh, a) != 0:
            a = None
        spec.append(a)
    return NamedSharding(mesh, PartitionSpec(*spec[: len(struct.shape)]))


def tree_shardings(structs, logical_tree, rules: ShardingRules):
    """Map a pytree of structs + matching pytree of logical tuples to
    NamedShardings."""
    return jax.tree.map(
        lambda s, l: fitted_sharding(s, l, rules),
        structs,
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(i, (str, type(None))) for i in x),
    )


def zero1_moment_specs(param_structs, param_logical, rules, quantized: bool):
    """Moment shardings: parameter sharding + ZeRO-1 over the data axis on
    the first divisible unsharded dim (fp32 moments).  Quantized moments are
    flat (n,)/(n/256,) arrays sharded over data when divisible."""
    mesh = rules.mesh
    data_size = mesh.shape.get("data", 1)

    def f32_spec(struct, logical):
        logical = tuple(logical) + (None,) * 10
        out = []
        used: set = set()
        for i, dim in enumerate(struct.shape):
            a = rules.assignment(logical[i])
            if a is not None and dim % _axes_size(mesh, a) == 0:
                out.append(a)
                used.update(a if isinstance(a, tuple) else (a,))
            else:
                out.append(None)
        # ZeRO-1 on top: place the data axis on the first free divisible dim
        # unless the parameter sharding (FSDP) already consumed it
        dp = rules.assignment("dp_shard")
        if dp and dp not in used:
            for i, dim in enumerate(struct.shape):
                if out[i] is None and dim % data_size == 0:
                    out[i] = dp
                    break
        return NamedSharding(mesh, PartitionSpec(*out))

    def leaf(struct, logical):
        if quantized:
            # int8 moments keep the parameter's own shape and sharding
            # (last-dim blockwise scales are tiny and unsharded on the
            # block dim) — see optim/adamw.py layout note
            q_sh = f32_spec(struct, logical)
            scale_spec = PartitionSpec(*(tuple(q_sh.spec)[:-1] + (None,)))
            if struct.ndim == 0:
                q_sh = NamedSharding(mesh, PartitionSpec(None))
                scale_spec = PartitionSpec(None)
            return {
                "m_q": q_sh,
                "m_s": NamedSharding(mesh, scale_spec),
                "v_q": q_sh,
                "v_s": NamedSharding(mesh, scale_spec),
            }
        s = f32_spec(struct, logical)
        return {"m": s, "v": s}

    return jax.tree.map(
        leaf,
        param_structs,
        param_logical,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(i, (str, type(None))) for i in x),
    )


# --------------------------------------------------------------------------- #
# model / step builders
# --------------------------------------------------------------------------- #
#: named sharding regimes (the §Perf hillclimb levers)
RULES_MODES = {
    "baseline": {},
    # weight-stationary experts + no FSDP on the (small) dense/attn weights:
    # kills the per-microbatch expert-weight all-gathers (arctic train)
    "moe_stationary": {"d_model": None, "expert_ff": "data"},
    # serve-mode 2D weight sharding: weights spread over (data x model),
    # activations replicated over data (tiny at decode), caches stay
    # batch-sharded — kills the FSDP weight gathers per decode step (jamba)
    "serve2d": {
        "d_model": None,
        "act_batch": None,
        "ff": ("data", "model"),
        "inner": ("data", "model"),
        "expert_ff": "data",
    },
}


def build_model(
    cfg: ArchConfig,
    mesh: Optional[jax.sharding.Mesh],
    flags: Optional[RuntimeFlags] = None,
    rules_mode: str = "baseline",
) -> Tuple[LanguageModel, Optional[ShardingRules]]:
    rules = None
    if mesh is not None:
        rules = make_rules(
            mesh,
            shard_heads=cfg.shard_heads_ok(mesh.shape["model"]),
            overrides=RULES_MODES[rules_mode],
        )
    flags = flags or RuntimeFlags()
    return LanguageModel(cfg, rules, flags), rules


def build_train_step(
    model: LanguageModel,
    lr: float = 3e-4,
    total_steps: int = 10000,
    micro_batches: int = 1,
):
    """fwd+bwd+AdamW.  ``micro_batches`` > 1 scans gradient accumulation
    over batch slices — the standard activation-memory lever (saved
    residuals shrink by the microbatch factor at identical math)."""

    def train_step(params, opt_state: AdamWState, batch):
        grad_fn = jax.value_and_grad(model.loss_fn, has_aux=True)
        if micro_batches > 1:
            mb = jax.tree.map(
                lambda a: a.reshape(
                    (micro_batches, a.shape[0] // micro_batches) + a.shape[1:]
                ),
                batch,
            )

            def body(acc, b_i):
                (l, metrics), g = grad_fn(params, b_i)
                acc_g, acc_l = acc
                return (
                    jax.tree.map(jnp.add, acc_g, g),
                    acc_l + l,
                ), metrics

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
            (grads, loss_sum), metrics = jax.lax.scan(
                body, (zero_g, jnp.zeros((), jnp.float32)), mb
            )
            grads = jax.tree.map(lambda g: g / micro_batches, grads)
            loss = loss_sum / micro_batches
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        lr_t = cosine_schedule(opt_state.step, lr, warmup=100, total=total_steps)
        new_params, new_state, om = adamw_update(grads, opt_state, params, lr_t)
        return new_params, new_state, {
            "loss": loss,
            **metrics,
            "grad_norm": om["grad_norm"],
        }

    return train_step


def build_prefill_step(model: LanguageModel, max_seq: int):
    def prefill_step(params, batch):
        return model.prefill(
            params, batch["tokens"], max_seq, batch.get("frontend")
        )

    return prefill_step


def build_decode_step(model: LanguageModel):
    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return decode_step


# --------------------------------------------------------------------------- #
# abstract inputs per (arch x shape)
# --------------------------------------------------------------------------- #
def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the batch of this cell."""
    B, S = shape.global_batch, shape.seq_len
    prefix = cfg.frontend_prefix if cfg.frontend else 0
    if shape.kind in ("train", "prefill"):
        out = {
            "tokens": jax.ShapeDtypeStruct((B, S - prefix), jnp.int32),
        }
        if prefix:
            out["frontend"] = jax.ShapeDtypeStruct(
                (B, prefix, cfg.d_model), jnp.bfloat16
            )
        return out
    # decode: one new token against a seq_len-deep cache
    return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


def _batch_logical(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, tuple]:
    out = {"tokens": ("batch", None)}
    if shape.kind in ("train", "prefill") and cfg.frontend:
        out["frontend"] = ("batch", None, None)
    return out


def train_arg_structs(model: LanguageModel, shape: ShapeConfig, rules: ShardingRules):
    """(arg structs, in_shardings, out_shardings) for the train step."""
    cfg = model.cfg
    params = model.abstract_params()
    p_logical = model.param_specs()
    quant = cfg.optimizer == "adamw8bit"
    opt = jax.eval_shape(lambda p: adamw_init(p, quantize=quant), params)
    batch = input_specs(cfg, shape)

    p_sh = tree_shardings(params, p_logical, rules)
    m_sh = zero1_moment_specs(params, p_logical, rules, quant)
    o_sh = AdamWState(
        step=NamedSharding(rules.mesh, PartitionSpec()), moments=m_sh
    )
    b_sh = tree_shardings(batch, _batch_logical(cfg, shape), rules)
    metrics_sh = jax.tree.map(
        lambda _: NamedSharding(rules.mesh, PartitionSpec()),
        {"loss": 0, "ce": 0, "aux": 0, "grad_norm": 0},
    )
    return (
        (params, opt, batch),
        (p_sh, o_sh, b_sh),
        (p_sh, o_sh, metrics_sh),
    )


def prefill_arg_structs(model: LanguageModel, shape: ShapeConfig, rules):
    cfg = model.cfg
    params = model.abstract_params()
    p_sh = tree_shardings(params, model.param_specs(), rules)
    batch = input_specs(cfg, shape)
    b_sh = tree_shardings(batch, _batch_logical(cfg, shape), rules)
    cache = model.cache_struct(shape.global_batch, shape.seq_len)
    c_sh = tree_shardings(cache, model.cache_specs(), rules)
    logits = jax.ShapeDtypeStruct(
        (shape.global_batch, 1, cfg.vocab_size), jnp.bfloat16
    )
    l_sh = fitted_sharding(logits, ("batch", None, "vocab"), rules)
    return (params, batch), (p_sh, b_sh), (l_sh, c_sh)


def decode_arg_structs(model: LanguageModel, shape: ShapeConfig, rules):
    cfg = model.cfg
    params = model.abstract_params()
    p_sh = tree_shardings(params, model.param_specs(), rules)
    cache = model.cache_struct(shape.global_batch, shape.seq_len)
    c_sh = tree_shardings(cache, model.cache_specs(), rules)
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    t_sh = fitted_sharding(tokens, ("batch", None), rules)
    logits = jax.ShapeDtypeStruct(
        (shape.global_batch, 1, cfg.vocab_size), jnp.bfloat16
    )
    l_sh = fitted_sharding(logits, ("batch", None, "vocab"), rules)
    return (params, cache, tokens), (p_sh, c_sh, t_sh), (l_sh, c_sh)
