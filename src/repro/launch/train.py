"""End-to-end fault-tolerant training driver.

Wires every substrate together: config -> model -> data pipeline ->
AdamW -> async sharded checkpointing -> the paper's prediction-aware
checkpointing policy (FaultTolerantExecutor).  On this container it runs
reduced configs on CPU; on a real pod the same driver runs the full config
under `jax.distributed` (the mesh came up in launch/mesh.py and every
array is GSPMD-sharded).

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --reduced --inject-faults --predictor paper-accurate
"""

from __future__ import annotations

import argparse
import math
import os
import time

import jax
import numpy as np

from .. import configs
from ..checkpoint import AsyncCheckpointer, CheckpointStore, latest_step
from ..core.events import make_event_trace
from ..core.predictor import SimulatedPredictor, predictor_preset
from ..core.waste import Platform, PredictorModel
from ..data.pipeline import SyntheticLMDataset
from ..ft import FaultInjector, FaultTolerantExecutor, WallClock
from ..models.layers import RuntimeFlags
from ..optim.adamw import adamw_init
from .steps import build_model, build_train_step


def make_train_state(cfg, model, seed: int = 0):
    params = model.init(jax.random.PRNGKey(seed))
    opt = adamw_init(params, quantize=cfg.optimizer == "adamw8bit")
    return {"params": params, "opt": opt}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--inject-faults", action="store_true")
    ap.add_argument("--fault-mtbf", type=float, default=20.0, help="seconds")
    ap.add_argument("--predictor", default=None, help="Table-3 preset name")
    ap.add_argument("--strategy", default="auto")
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model, _ = build_model(cfg, mesh=None, flags=RuntimeFlags(dense_attn_max=512))
    state = make_train_state(cfg, model, args.seed)
    step_fn_inner = jax.jit(build_train_step(model, lr=args.lr,
                                             total_steps=args.steps,
                                             micro_batches=args.micro))

    data = SyntheticLMDataset(
        vocab_size=cfg.vocab_size,
        seq_len=args.seq,
        global_batch=args.batch,
        seed=args.seed,
        frontend_prefix=cfg.frontend_prefix if cfg.frontend else 0,
        d_model=cfg.d_model,
    )

    store = CheckpointStore(args.ckpt_dir, codec="raw")
    ckpt = AsyncCheckpointer(store, keep=3)

    losses = {}

    def step_fn(st, k):
        batch = {kk: jax.numpy.asarray(v) for kk, v in data.batch(k).items()}
        new_params, new_opt, metrics = step_fn_inner(
            st["params"], st["opt"], batch
        )
        losses[k] = float(metrics["loss"])
        if k % 10 == 0:
            print(
                f"step {k:5d} loss {losses[k]:.4f} gnorm "
                f"{float(metrics['grad_norm']):.3f}",
                flush=True,
            )
        return {"params": new_params, "opt": new_opt}

    # -- fault tolerance wiring ------------------------------------------- #
    plat = Platform(
        mu=args.fault_mtbf, C=0.5, D=0.2, R=0.5, M=0.3
    )  # CPU-scale priors; C is re-estimated from measured saves
    pm = None
    predictor = None
    injector = None
    if args.inject_faults:
        preset = (
            predictor_preset(args.predictor)
            if args.predictor
            else PredictorModel(0.0, 1.0)
        )
        pm = PredictorModel(
            preset.recall, preset.precision, lead=5.0, window=min(preset.window, 2.0)
        )
        horizon = args.steps * 5.0 + 600
        trace = make_event_trace(
            np.random.default_rng(args.seed + 7),
            horizon=horizon,
            mtbf=plat.mu,
            recall=pm.recall,
            precision=pm.precision,
            window=pm.window,
            lead=pm.lead,
        )
        injector = FaultInjector(trace)
        if args.predictor:
            predictor = SimulatedPredictor(trace, pm)

    def save_state(st):
        return st

    def restore_fn(step_k):
        s = latest_step(args.ckpt_dir)
        if s is None:
            return make_train_state(cfg, model, args.seed)
        return store.restore(s, target=jax.eval_shape(lambda: state))

    def load_state(st, tree, step_k):
        return tree

    ex = FaultTolerantExecutor(
        step_fn=step_fn,
        state=state,
        platform=plat,
        pred_model=pm,
        predictor=predictor,
        checkpointer=ckpt,
        save_state=save_state,
        load_state=load_state,
        restore_fn=restore_fn if args.inject_faults else None,
        injector=injector,
        clock=WallClock(),
        strategy=args.strategy if predictor else "young",
    )
    t0 = time.time()
    report = ex.run(args.steps)
    dt = time.time() - t0
    print("\n== run report ==")
    print(report.summary())
    print("ledger:", {k: round(v, 2) for k, v in report.ledger.as_dict().items()})
    print(f"wall time: {dt:.1f}s; final loss: {losses.get(args.steps - 1)}")


if __name__ == "__main__":
    main()
