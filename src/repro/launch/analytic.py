"""Analytic FLOP/byte models per (arch x shape) — the roofline cross-check.

MODEL_FLOPS follows the assignment: 6*N*D for training (N = params, D =
tokens), 6*N_active*D for MoE; serve steps use 2*N(_active)*tokens.
Attention's quadratic term (not part of 6ND) is reported separately so the
HLO-vs-model ratio isolates remat/redundancy waste rather than attention
bookkeeping.

The byte model estimates per-step HBM traffic per device (weights, moments,
activations at the remat policy's granularity, KV/state caches) — used as
a sanity band around the HLO-derived bytes, not as the primary number.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from ..configs.base import ArchConfig, ShapeConfig

__all__ = ["model_flops", "attention_flops", "analytic_summary"]


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Assignment MODEL_FLOPS (global, per step)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def attention_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Quadratic attention term (global, per step), excluded from 6ND."""
    n_attn = sum(1 for s in cfg.pattern if s.mixer == "attn") * cfg.n_repeats
    if n_attn == 0:
        return 0.0
    hd = cfg.resolved_head_dim
    H = cfg.num_heads
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        # fwd QK^T + AV = 4*B*S^2*H*hd; backward ~2x fwd
        return 3.0 * 4.0 * B * S * S * H * hd * n_attn
    if shape.kind == "prefill":
        return 4.0 * B * S * S * H * hd * n_attn
    # decode: one query against S cache entries
    return 4.0 * B * S * H * hd * n_attn


def analytic_summary(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    return {
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "model_flops": model_flops(cfg, shape),
        "attention_flops": attention_flops(cfg, shape),
    }
