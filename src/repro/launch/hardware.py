"""TPU v5e-class hardware constants for the roofline model (assignment)."""

PEAK_FLOPS_BF16 = 197e12  # FLOP/s per chip
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link
VMEM_BYTES = 16 * 2**20  # ~16 MiB usable VMEM per core
HBM_BYTES = 16 * 2**30  # 16 GiB HBM per chip

CHIPS_PER_POD = 256  # 16 x 16
