"""Production mesh construction (assignment MULTI-POD DRY-RUN spec).

A function, not a module-level constant — importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax

__all__ = ["make_mesh_compat", "make_production_mesh"]


def make_mesh_compat(shape, names) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types across JAX versions.

    ``jax.sharding.AxisType`` and the ``axis_types=`` keyword only exist in
    newer JAX; older releases default to Auto semantics anyway.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                shape, names, axis_types=(axis_type.Auto,) * len(names)
            )
        except TypeError:
            pass
    return jax.make_mesh(shape, names)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 (single v5e-class pod, 256 chips) or 2x16x16 (2 pods, 512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)
