"""Batched serving driver with fault-tolerant decode.

Prefill + decode loop over batched requests; a prediction-aware snapshot
policy protects the KV/state cache and request queue exactly like the
training executor protects optimizer state: on a trusted prediction the
server snapshots (cache, queue cursor) before the window; on a fault it
restores and replays only the tokens since the snapshot.  Serving "waste"
is re-decoded tokens + snapshot time, and the same Section-3 calculus
picks the snapshot period.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --requests 4 --prompt-len 32 --gen 48 --inject-faults
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..core.events import make_event_trace
from ..core.waste import Platform
from ..models.layers import RuntimeFlags
from .steps import build_decode_step, build_model, build_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=48)
    ap.add_argument("--snapshot-every", type=int, default=16, help="tokens")
    ap.add_argument("--inject-faults", action="store_true")
    ap.add_argument("--fault-mtbf", type=float, default=4.0, help="seconds")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced()
    model, _ = build_model(cfg, mesh=None, flags=RuntimeFlags(dense_attn_max=512))
    params = model.init(jax.random.PRNGKey(args.seed))

    B = args.requests
    max_seq = args.prompt_len + (cfg.frontend_prefix or 0) + args.gen + 8
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, args.prompt_len)), jnp.int32
    )
    frontend = None
    if cfg.frontend:
        frontend = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_prefix, cfg.d_model)) * 0.02,
            jnp.bfloat16,
        )

    prefill = jax.jit(lambda p, b: build_prefill_step(model, max_seq)(p, b))
    decode = jax.jit(build_decode_step(model))

    # fault trace in wall time
    fault_times = []
    if args.inject_faults:
        tr = make_event_trace(
            np.random.default_rng(args.seed + 3),
            horizon=600.0,
            mtbf=args.fault_mtbf,
            recall=0.0,
            precision=1.0,
        )
        fault_times = [f.time for f in tr.faults]

    t_start = time.monotonic()
    fi = 0
    n_faults = 0
    redecoded = 0

    logits, cache = prefill(params, {"tokens": prompts, "frontend": frontend})
    out_tokens = [jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]]
    snapshot = (jax.tree.map(lambda x: x, cache), 1)  # (cache copy, n tokens)

    k = 1
    while k < args.gen:
        now = time.monotonic() - t_start
        if fi < len(fault_times) and fault_times[fi] <= now:
            fi += 1
            n_faults += 1
            # restore snapshot, replay tokens generated since
            cache, k_snap = snapshot
            redecoded += k - k_snap
            out_tokens = out_tokens[:k_snap]
            k = k_snap
            print(f"fault at t={now:.1f}s -> restored to token {k}", flush=True)
            continue
        logits, cache = decode(params, cache, out_tokens[-1])
        out_tokens.append(jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None])
        k += 1
        if k % args.snapshot_every == 0:
            snapshot = (jax.tree.map(lambda x: x, cache), k)

    toks = jnp.concatenate(out_tokens, axis=1)
    dt = time.monotonic() - t_start
    print(f"generated {toks.shape} tokens in {dt:.1f}s "
          f"({B * args.gen / dt:.1f} tok/s), faults={n_faults}, "
          f"re-decoded={redecoded} tokens")
    print("sample:", np.asarray(toks[0][:16]))


if __name__ == "__main__":
    main()
