import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment deliverable (e)).

For every (architecture x input shape x mesh) cell:
``jax.jit(step, in_shardings, out_shardings).lower(*input_specs).compile()``
must succeed on the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh.
The compiled artifact yields:

* ``memory_analysis()``  — per-device bytes (proves the cell fits);
* ``cost_analysis()``    — XLA's own FLOP/byte counts (while-body-once,
  kept for reference);
* the while-aware HLO parse (hlo_analysis.py) — scan-corrected FLOPs,
  bytes and collective wire bytes, from which the three roofline terms
  are derived (hardware constants in hardware.py).

Results are cached as JSON under results/dryrun/ so EXPERIMENTS.md and the
benchmarks read from the cache instead of recompiling.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
        --shape train_4k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod
"""

import argparse
import json
from typing import Optional
import time
import traceback

import jax

from .. import configs
from ..configs.base import SHAPES, shape_applicable
from ..models.layers import RuntimeFlags
from . import hardware as hw
from .analytic import analytic_summary
from .hlo_analysis import analyze_hlo
from .mesh import make_production_mesh
from .steps import (
    build_decode_step,
    build_model,
    build_prefill_step,
    build_train_step,
    decode_arg_structs,
    prefill_arg_structs,
    train_arg_structs,
)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


def _flags_for(shape, overrides=None) -> RuntimeFlags:
    kw = {
        "attn_impl": "auto",
        # training uses the chunked (flash-style) path from 4k up: dense
        # scores at (B/dp, H/tp, S, S) f32 blow VMEM/HBM budgets
        "dense_attn_max": 2048 if shape.kind == "train" else 8192,
        "kv_chunk": 1024,
        "remat_policy": "full" if shape.kind == "train" else "none",
    }
    if overrides:
        kw.update(overrides)
    return RuntimeFlags(**kw)


def _pick_micro_batches(cfg, shape, mesh, budget_bytes: float = 4e9) -> int:
    """Smallest microbatch count keeping the per-device saved-residual
    stack (L x B_local/m x S x D x 2B) under ~4 GB."""
    data = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    b_local = max(shape.global_batch // data, 1)
    need = cfg.num_layers * b_local * shape.seq_len * cfg.d_model * 2
    m = 1
    while m < b_local and need / m > budget_bytes:
        m *= 2
    return min(m, b_local)


def run_cell(
    arch_name: str,
    shape_name: str,
    multi_pod: bool,
    flag_overrides=None,
    tag: str = "baseline",
    save: bool = True,
    micro_batches: Optional[int] = None,
    rules_mode: str = "baseline",
) -> dict:
    cfg = configs.get(arch_name)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch_name, "shape": shape_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    flags = _flags_for(shape, flag_overrides)
    model, rules = build_model(cfg, mesh, flags, rules_mode=rules_mode)

    t0 = time.time()
    if shape.kind == "train":
        if micro_batches is None:
            micro_batches = _pick_micro_batches(cfg, shape, mesh)
        step = build_train_step(model, micro_batches=micro_batches)
        args, in_sh, out_sh = train_arg_structs(model, shape, rules)
        donate = (0, 1)
    elif shape.kind == "prefill":
        step = build_prefill_step(model, shape.seq_len)
        args, in_sh, out_sh = prefill_arg_structs(model, shape, rules)
        donate = ()
    else:
        step = build_decode_step(model)
        args, in_sh, out_sh = decode_arg_structs(model, shape, rules)
        donate = (1,)

    with mesh:
        lowered = jax.jit(
            step, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=donate
        ).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = analyze_hlo(compiled.as_text())

    # ---- roofline terms (per assignment; chips x peak) -------------------- #
    # parser numbers are per-device (the HLO is the per-device program)
    t_comp = hlo.flops / hw.PEAK_FLOPS_BF16
    t_mem = hlo.bytes / hw.HBM_BW
    t_coll = hlo.collective_bytes / hw.ICI_BW
    dominant = max(
        [("compute", t_comp), ("memory", t_mem), ("collective", t_coll)],
        key=lambda kv: kv[1],
    )[0]
    ana = analytic_summary(cfg, shape)
    useful_frac = ana["model_flops"] / max(hlo.flops * n_chips, 1.0)

    result = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "tag": tag,
        "kind": shape.kind,
        "micro_batches": micro_batches,
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_est_bytes": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes
            + mem.output_size_in_bytes
            - mem.alias_size_in_bytes,
            "fits_hbm": (
                mem.argument_size_in_bytes
                + mem.temp_size_in_bytes
                + mem.output_size_in_bytes
                - mem.alias_size_in_bytes
            )
            <= hw.HBM_BYTES,
        },
        "xla_cost_analysis": {
            "flops": ca.get("flops"),
            "bytes_accessed": ca.get("bytes accessed"),
        },
        "hlo": hlo.as_dict(),
        "analytic": ana,
        "roofline": {
            "t_compute_s": t_comp,
            "t_memory_s": t_mem,
            "t_collective_s": t_coll,
            "dominant": dominant,
            "step_lower_bound_s": max(t_comp, t_mem, t_coll),
            "useful_flops_fraction": useful_frac,
            "roofline_fraction": min(
                1.0,
                (ana["model_flops"] + ana["attention_flops"])
                / (max(t_comp, t_mem, t_coll) * n_chips * hw.PEAK_FLOPS_BF16 + 1e-9),
            ),
        },
    }
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        fname = f"{arch_name}__{shape_name}__{result['mesh']}__{tag}.json"
        with open(os.path.join(RESULTS_DIR, fname), "w") as f:
            json.dump(result, f, indent=1)
    return result


def _fmt(result: dict) -> str:
    if "skipped" in result:
        return f"SKIP {result['arch']:24s} {result['shape']:12s} {result['skipped']}"
    r = result["roofline"]
    m = result["memory"]
    return (
        f"OK   {result['arch']:24s} {result['shape']:12s} {result['mesh']:8s} "
        f"compile={result['t_compile_s']:6.1f}s "
        f"mem/dev={m['peak_est_bytes']/2**30:6.2f}GiB fits={m['fits_hbm']} "
        f"t_comp={r['t_compute_s']*1e3:8.2f}ms t_mem={r['t_memory_s']*1e3:8.2f}ms "
        f"t_coll={r['t_collective_s']*1e3:8.2f}ms dom={r['dominant']:10s} "
        f"useful={r['useful_flops_fraction']:.2f}"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--remat", default=None, choices=["none", "full", "dots"])
    ap.add_argument("--rules", default="baseline",
                    choices=["baseline", "moe_stationary", "serve2d"])
    ap.add_argument("--micro", type=int, default=None)
    ap.add_argument("--attn", default=None, choices=["auto", "dense", "chunked"])
    args = ap.parse_args()

    overrides = {}
    if args.remat:
        overrides["remat_policy"] = args.remat
    if args.attn:
        overrides["attn_impl"] = args.attn

    archs = configs.ARCH_NAMES if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    res = run_cell(arch, shape, mp, overrides or None, tag=args.tag,
                                   rules_mode=args.rules, micro_batches=args.micro)
                    print(_fmt(res), flush=True)
                    if "skipped" not in res:
                        print(
                            "     memory_analysis:",
                            {k: v for k, v in res["memory"].items()},
                            flush=True,
                        )
                        print(
                            "     cost_analysis:",
                            res["xla_cost_analysis"],
                            "| hlo(flops=%.3e bytes=%.3e coll=%.3e)"
                            % (
                                res["hlo"]["flops"],
                                res["hlo"]["bytes"],
                                res["hlo"]["collective_bytes"],
                            ),
                            flush=True,
                        )
                except Exception as e:
                    failures += 1
                    print(f"FAIL {arch} {shape} multipod={mp}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
