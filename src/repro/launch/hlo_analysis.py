"""While-aware cost extraction from compiled HLO text.

XLA:CPU's ``compiled.cost_analysis()`` counts a ``while`` body **once**,
which silently drops L-1 of L scanned layers (and every token of an SSM
scan) from FLOP/byte totals.  This parser walks the HLO text instead:

1. split the module into computations; build a per-computation symbol
   table (op name -> output shape/dtype);
2. build the call graph: ``while`` ops carry ``known_trip_count`` in their
   backend_config (fallback: the loop-bound constant in the condition);
   fusion/call/reduce bodies multiply by 1;
3. propagate execution weights from ENTRY through the DAG;
4. accumulate, per weighted computation:
   * **flops** from ``dot`` ops (2 x prod(out) x prod(contracting dims)),
     including dots inside fusion interiors;
   * **bytes** from top-level op operands+outputs (fusion interiors
     excluded — a fusion's HBM traffic is its operands/results), with
     dynamic-slice/update fusions charged at slice size, matching real
     per-iteration traffic of scanned stacked weights;
   * **collective wire bytes** per op kind with ring-cost factors
     (all-reduce 2(g-1)/g, all-gather/reduce-scatter/all-to-all (g-1)/g,
     collective-permute 1), split by replica-group size so the roofline
     can attribute traffic to mesh axes.

This is the "profile" used for §Roofline and the §Perf hillclimb —
structural, from the compiled artifact, as the assignment prescribes.
"""

from __future__ import annotations

import json
import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["HLOCost", "analyze_hlo", "collective_summary"]

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "f16": 2,
    "bf16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
    "c64": 8,
    "c128": 16,
    "f8e4m3fn": 1,
    "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")
_KIND_RE = re.compile(r"^\s*([\w\-]+)\(")


def _parse_op_line(line: str):
    """Parse '%name = TYPE kind(rest' robustly.

    TYPE is either a single shape token or a parenthesized tuple type that
    may contain '/*index=N*/' comments — we match the tuple's parens by
    depth instead of regexing through them."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i + 1
                    break
        out_type = rest[:end]
        rest = rest[end:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        out_type = rest[:sp]
        rest = rest[sp:]
    km = _KIND_RE.match(rest)
    if not km:
        return None
    kind = km.group(1)
    return name, out_type, kind, rest[km.end():]
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->")
_PARAM_RE = re.compile(r"([\w\.\-]+)\s*:\s*(\w+\[[\d,]*\])")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# ring wire-cost factor per element byte, as a function of group size g
def _wire_factor(kind: str, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g
    if kind in ("all-gather", "reduce-scatter", "all-to-all"):
        return (g - 1) / g
    if kind == "collective-permute":
        return 1.0
    return 1.0


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",")] if dims else []


@dataclass
class _Op:
    name: str
    kind: str
    out_type: str
    rest: str  # text after the opening paren of operands


@dataclass
class _Comp:
    name: str
    is_entry: bool = False
    params: Dict[str, str] = field(default_factory=dict)
    ops: List[_Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)


@dataclass
class HLOCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0  # wire bytes per device
    collective_by_kind: Dict[str, float] = field(default_factory=dict)
    collective_by_group: Dict[int, float] = field(default_factory=dict)
    n_collectives: float = 0.0
    warnings: List[str] = field(default_factory=list)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_bytes": self.collective_bytes,
            "collective_by_kind": self.collective_by_kind,
            "collective_by_group": {str(k): v for k, v in self.collective_by_group.items()},
            "n_collectives": self.n_collectives,
            "warnings": self.warnings,
        }


def _parse_computations(text: str) -> Tuple[Dict[str, _Comp], Optional[str]]:
    comps: Dict[str, _Comp] = {}
    entry = None
    current: Optional[_Comp] = None
    for raw in text.splitlines():
        if raw and not raw.startswith(" ") and ("->" in raw):
            m = _COMP_HDR_RE.match(raw.strip())
            if m:
                is_entry = bool(m.group(1))
                name = m.group(2)
                current = _Comp(name=name, is_entry=is_entry)
                for pname, ptype in _PARAM_RE.findall(m.group(3)):
                    current.params[pname] = ptype
                    current.symbols[pname] = ptype
                comps[name] = current
                if is_entry:
                    entry = name
                continue
        line = raw.strip()
        if current is None or not line or line.startswith("//"):
            continue
        if line == "}":
            continue
        parsed = _parse_op_line(line)
        if parsed:
            name, out_type, kind, rest = parsed
            current.ops.append(_Op(name, kind, out_type, rest))
            current.symbols[name] = out_type
    return comps, entry


def _while_refs(op: _Op) -> Tuple[Optional[str], Optional[str], Optional[int]]:
    cond = body = None
    mc = re.search(r"condition=%?([\w\.\-]+)", op.rest)
    mb = re.search(r"body=%?([\w\.\-]+)", op.rest)
    if mc:
        cond = mc.group(1)
    if mb:
        body = mb.group(1)
    trip = None
    mt = _TRIP_RE.search(op.rest)
    if mt:
        trip = int(mt.group(1))
    return cond, body, trip


def _calls_refs(op: _Op) -> List[str]:
    out = []
    for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", op.rest):
        out.append(m.group(1))
    return out


def _cond_trip_count(comp: _Comp) -> Optional[int]:
    """Fallback: max integer constant in the loop condition computation."""
    best = None
    for op in comp.ops:
        m = re.match(r"constant\((\d+)\)", op.rest)
        if op.kind == "constant" and m:
            v = int(m.group(1))
            if best is None or v > best:
                best = v
    return best


def analyze_hlo(text: str) -> HLOCost:
    comps, entry = _parse_computations(text)
    cost = HLOCost(collective_by_kind=defaultdict(float), collective_by_group=defaultdict(float))
    if entry is None:
        cost.warnings.append("no ENTRY computation found")
        cost.collective_by_kind = dict(cost.collective_by_kind)
        cost.collective_by_group = dict(cost.collective_by_group)
        return cost

    # ---- build call graph with multipliers --------------------------------- #
    # control computations get byte accounting; fused/applied ones only flops
    weights: Dict[str, float] = defaultdict(float)
    control: Dict[str, bool] = defaultdict(bool)
    loop_body: Dict[str, bool] = defaultdict(bool)
    weights[entry] = 1.0
    control[entry] = True

    # topological propagation via worklist (HLO call graphs are DAGs)
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        for op in comp.ops:
            if op.kind == "while":
                cond, body, trip = _while_refs(op)
                if trip is None and cond in comps:
                    trip = _cond_trip_count(comps[cond])
                if trip is None:
                    trip = 1
                    cost.warnings.append(f"unknown trip count for {op.name}")
                for ref, mult in ((body, trip), (cond, trip + 1)):
                    if ref:
                        weights[ref] += weights[cname] * mult
                        control[ref] = True
                        loop_body[ref] = True
                        if ref not in seen:
                            seen.add(ref)
                            order.append(ref)
            elif op.kind in ("call", "conditional"):
                for ref in _calls_refs(op) or _OPERAND_RE.findall(op.rest)[:0]:
                    weights[ref] += weights[cname]
                    control[ref] = True
                    if ref not in seen:
                        seen.add(ref)
                        order.append(ref)
            else:
                for ref in _calls_refs(op):
                    weights[ref] += weights[cname]
                    # fusion interiors: flops only
                    if ref not in seen:
                        seen.add(ref)
                        order.append(ref)

    # ---- accumulate --------------------------------------------------------- #
    VMEM = 16 * 2**20  # v5e-class usable VMEM per core

    def _interior_slice_bytes(op: _Op) -> Optional[int]:
        """If a fusion's interior slices/gathers from its (possibly huge)
        operands, the fusion's real traffic is its output + the interior
        slice sizes, not the full operand buffers."""
        if op.kind != "fusion":
            return None
        refs = _calls_refs(op)
        interior = comps.get(refs[0]) if refs else None
        if interior is None:
            return None
        total = 0
        found = False
        for o in interior.ops:
            if o.kind in ("dynamic-slice", "gather"):
                found = True
                total += _shape_bytes(o.out_type)
        return total if found else None

    def _op_footprint(comp: _Comp, op: _Op) -> int:
        operands = _OPERAND_RE.findall(
            op.rest.split(", calls=")[0].split(", metadata=")[0]
        )
        out_b = _shape_bytes(op.out_type)
        in_b = sum(_shape_bytes(comp.symbols.get(o, "")) for o in operands)
        isl = _interior_slice_bytes(op)
        if isl is not None:
            in_b = min(in_b, isl + out_b)
        return out_b + in_b

    for cname, comp in comps.items():
        w = weights.get(cname, 0.0)
        if w <= 0.0:
            continue
        is_control = control.get(cname, False)
        # Fine-grained loop bodies (per-token SSM scans etc.) whose working
        # set fits VMEM are fused on-chip on the TPU target: only their
        # streamed slices (dynamic-slice/update) and collectives touch HBM,
        # not every intermediate.
        vmem_resident = False
        if loop_body.get(cname) and is_control:
            big = max(
                (
                    _op_footprint(comp, op)
                    for op in comp.ops
                    if op.kind
                    not in ("tuple", "get-tuple-element", "parameter", "while",
                            "copy", "bitcast")
                    # slice streams (xs/ys of the scan) touch HBM at slice
                    # granularity and don't disqualify VMEM residency of
                    # the compute intermediates
                    and "dynamic" not in op.name
                    and op.kind
                    not in ("dynamic-slice", "dynamic-update-slice", "gather",
                            "scatter")
                ),
                default=0,
            )
            vmem_resident = big <= VMEM
        for op in comp.ops:
            if op.kind == "dot":
                operands = _OPERAND_RE.findall(op.rest)
                lhs_type = comp.symbols.get(operands[0], "") if operands else ""
                _, out_dims = _shape_dims(op.out_type)
                _, lhs_dims = _shape_dims(lhs_type)
                mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
                contract = 1
                if mcd and lhs_dims:
                    for d in mcd.group(1).split(","):
                        if d:
                            contract *= lhs_dims[int(d)]
                flops = 2.0 * math.prod(out_dims or [1]) * contract
                cost.flops += w * flops
                if is_control:
                    in_bytes = sum(
                        _shape_bytes(comp.symbols.get(o, "")) for o in operands
                    )
                    cost.bytes += w * (in_bytes + _shape_bytes(op.out_type))
                continue
            if op.kind == "convolution":
                cost.warnings.append("convolution flops not modelled")
            if op.kind in COLLECTIVES and is_control:
                out_b = _shape_bytes(op.out_type)
                g = None
                mg = _GROUPS_RE.search(op.rest)
                if mg:
                    g = int(mg.group(2))
                else:
                    mo = _GROUPS_OLD_RE.search(op.rest)
                    if mo:
                        first = mo.group(1).split("},")[0].strip("{}")
                        g = len([t for t in first.split(",") if t.strip() != ""])
                if g is None:
                    g = 2
                    cost.warnings.append(f"no replica_groups on {op.name}")
                wire = out_b * _wire_factor(op.kind, g)
                cost.collective_bytes += w * wire
                cost.collective_by_kind[op.kind] += w * wire
                cost.collective_by_group[g] += w * wire
                cost.n_collectives += w
                cost.bytes += w * 2 * out_b
                continue
            if not is_control:
                continue
            if op.kind in (
                "tuple",
                "get-tuple-element",
                "bitcast",
                "parameter",
                "constant",
                "after-all",
                "while",
                "iota",
                "broadcast",
                # XLA:CPU materializes loop-carry aliasing as `copy` ops —
                # full stacked-residual buffers copied per iteration.  TPU
                # buffer assignment aliases these away; counting them would
                # dominate the byte total with traffic that does not exist
                # on the target.
                "copy",
            ):
                continue
            # generic top-level op: operands + output bytes
            out_b = _shape_bytes(op.out_type)
            operands = _OPERAND_RE.findall(
                op.rest.split(", calls=")[0].split(", metadata=")[0]
            )
            in_b = sum(_shape_bytes(comp.symbols.get(o, "")) for o in operands)
            # dynamic-slice/update (incl. fusions named after them) touch
            # only the slice, not the whole buffer they index into.  For
            # dynamic-update-slice the *output* type is the full buffer, so
            # the slice size is the smallest real operand (the update).
            sliceish = "dynamic" in op.name or (
                op.kind
                in ("dynamic-slice", "dynamic-update-slice", "scatter", "gather")
            )
            if sliceish:
                slice_b = min(
                    [out_b]
                    + [
                        b
                        for b in (
                            _shape_bytes(comp.symbols.get(o, ""))
                            for o in operands
                        )
                        if b > 0
                    ]
                )
                cost.bytes += w * 2 * slice_b
                continue
            isl = _interior_slice_bytes(op)
            if isl is not None:  # fusion slicing big buffers internally
                cost.bytes += w * (min(in_b, isl + out_b) + out_b)
                continue
            if vmem_resident:
                continue
            cost.bytes += w * (in_b + out_b)

    cost.collective_by_kind = dict(cost.collective_by_kind)
    cost.collective_by_group = dict(cost.collective_by_group)
    return cost


def collective_summary(cost: HLOCost) -> str:
    parts = [f"{k}: {v/1e6:.1f}MB" for k, v in sorted(cost.collective_by_kind.items())]
    return ", ".join(parts) if parts else "none"
