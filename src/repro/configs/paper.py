"""The paper's Section 5 platform scenarios.

C = R = 10 mn, D = 1 mn, individual MTBF 125 years, N from 2^14 to 2^19
(platform MTBF from ~4000 mn down to ~125 mn).
"""

from ..core.waste import Platform

MN = 60.0

C = 10 * MN
D = 1 * MN
R = 10 * MN
MU_IND_YEARS = 125.0
MU_IND = MU_IND_YEARS * 365.25 * 86400.0

N_RANGE = [2**k for k in range(14, 20)]


def platform(n_procs: int, M: float | None = None) -> Platform:
    return Platform.from_components(MU_IND, n_procs, C, D, R, M=M)
