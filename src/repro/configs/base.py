"""Architecture and shape configuration system.

Every assigned architecture gets one ``<id>.py`` file exporting ``CONFIG``;
``repro.configs.get(name)`` resolves them.  ``ArchConfig.reduced()`` yields
a same-family scaled-down config for CPU smoke tests.  Shape suites follow
the assignment: train_4k / prefill_32k / decode_32k / long_500k.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence, Tuple

__all__ = [
    "MoESpec",
    "SSMSpec",
    "FTSpec",
    "LayerSpec",
    "ArchConfig",
    "ShapeConfig",
    "SHAPES",
    "shape_applicable",
]


@dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    dense_residual: bool = False  # arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMSpec:
    # mamba
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None  # ceil(d_model/16) by default
    # rwkv6
    rwkv_head_dim: int = 64
    decay_lora: int = 64


@dataclass(frozen=True)
class FTSpec:
    """Fault-tolerance parameters feeding the paper's policy (Section 5
    defaults; C is measured live by the executor and these act as priors)."""

    n_nodes: int = 512
    mu_ind: float = 125 * 365.25 * 86400.0  # individual MTBF: 125 years (s)
    C: float = 600.0  # checkpoint cost prior (s)
    D: float = 60.0  # downtime (s)
    R: float = 600.0  # recovery (s)
    M: float = 300.0  # migration cost (s)
    predictor: str = "paper-accurate"

    @property
    def mu(self) -> float:
        return self.mu_ind / self.n_nodes


@dataclass(frozen=True)
class LayerSpec:
    """One position of the repeating block pattern."""

    mixer: str  # "attn" | "mamba" | "rwkv"
    mlp: str  # "dense" | "moe" | "none" (rwkv has its own channel mix)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    moe: Optional[MoESpec] = None
    ssm: SSMSpec = field(default_factory=SSMSpec)
    pattern: Tuple[LayerSpec, ...] = (LayerSpec("attn", "dense"),)
    tie_embeddings: bool = False
    # modality frontends are stubs: input_specs() provides precomputed
    # frame/patch embeddings of this prefix length
    frontend: Optional[str] = None  # "audio_frames" | "vision_patches"
    frontend_prefix: int = 0
    # whether attention is quadratic in seq (long_500k applicability)
    subquadratic: bool = False
    # sharding policy: head TP only when the head count divides the axis
    param_dtype: str = "float32"  # "bfloat16" for the 400B-class archs
    optimizer: str = "adamw"  # "adamw8bit" for the 400B-class archs
    ft: FTSpec = field(default_factory=FTSpec)
    source: str = ""

    def __post_init__(self):
        if self.num_layers % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not divisible by "
                f"pattern length {len(self.pattern)}"
            )

    # ------------------------------------------------------------------ #
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // max(self.num_heads, 1)

    @property
    def n_repeats(self) -> int:
        return self.num_layers // len(self.pattern)

    def shard_heads_ok(self, tp: int = 16) -> bool:
        if self.num_heads == 0:
            return True  # attention-free
        return self.num_heads % tp == 0

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.ssm.rwkv_head_dim

    # ------------------------------------------------------------------ #
    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        H, KV, hd = self.num_heads, self.num_kv_heads, self.resolved_head_dim
        total = V * D  # embedding
        if not self.tie_embeddings:
            total += D * V  # head
        total += D  # final norm
        for spec in self.pattern:
            n = self.n_repeats
            if spec.mixer == "attn":
                attn = D * H * hd + 2 * D * KV * hd + H * hd * D
                if self.qkv_bias:
                    attn += (H + 2 * KV) * hd
                total += n * (attn + D)  # + norm
            elif spec.mixer == "mamba":
                din, ds = self.d_inner, self.ssm.d_state
                dtr = self.ssm.dt_rank or math.ceil(D / 16)
                m = (
                    D * 2 * din  # in_proj
                    + din * self.ssm.d_conv  # conv
                    + din * (dtr + 2 * ds)  # x_proj
                    + dtr * din  # dt_proj
                    + din * ds  # A_log
                    + din  # D skip
                    + din * D  # out_proj
                )
                total += n * (m + D)
            elif spec.mixer == "rwkv":
                hdim = self.ssm.rwkv_head_dim
                nh = self.rwkv_heads
                lora = self.ssm.decay_lora
                tm = (
                    5 * D  # token-shift mixes
                    + D * lora
                    + lora * nh * hdim  # decay lora
                    + nh * hdim  # w0
                    + nh * hdim  # u bonus
                    + 4 * D * nh * hdim  # r,k,v,g projections
                    + nh * hdim * D  # output
                    + nh * hdim  # group norm
                )
                cm = 2 * D + D * F + F * D + D * D  # channel mix
                total += n * (tm + cm + 2 * D)
            if spec.mlp == "dense":
                total += self.n_repeats * (3 * D * F + D)
            elif spec.mlp == "moe":
                assert self.moe is not None
                e = self.moe.num_experts
                total += self.n_repeats * (D * e + e * 3 * D * F + D)
                if self.moe.dense_residual:
                    total += self.n_repeats * 3 * D * F
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        e, k = self.moe.num_experts, self.moe.top_k
        expert_params = 0
        for spec in self.pattern:
            if spec.mlp == "moe":
                expert_params += self.n_repeats * e * 3 * self.d_model * self.d_ff
        return full - expert_params + int(expert_params * (k / e))

    # ------------------------------------------------------------------ #
    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        pat = len(self.pattern)
        moe = None
        if self.moe is not None:
            moe = replace(self.moe, num_experts=8, top_k=min(self.moe.top_k, 2))
        return replace(
            self,
            name=self.name + "-reduced",
            num_layers=max(pat, 2 if pat == 1 else pat),
            d_model=64,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            moe=moe,
            ssm=replace(self.ssm, d_state=8, rwkv_head_dim=16, decay_lora=8),
            frontend_prefix=8 if self.frontend else 0,
            param_dtype="float32",
            optimizer="adamw",
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment rule: long_500k only for sub-quadratic (SSM/hybrid) archs."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, "long_500k skipped: pure full-attention architecture"
    return True, ""
