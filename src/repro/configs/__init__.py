"""Assigned-architecture registry.

``get("qwen2-72b")`` returns the exact published config; ``get(name).reduced()``
is the CPU smoke-test variant.  ``--arch <id>`` in the launchers resolves
through this registry.
"""

from __future__ import annotations

from importlib import import_module
from typing import Dict, List

from .base import (
    ArchConfig,
    FTSpec,
    LayerSpec,
    MoESpec,
    ShapeConfig,
    SHAPES,
    SSMSpec,
    shape_applicable,
)

_MODULES = {
    "arctic-480b": "arctic_480b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "granite-8b": "granite_8b",
    "qwen2-0.5b": "qwen2_0_5b",
    "qwen2-72b": "qwen2_72b",
    "smollm-135m": "smollm_135m",
    "musicgen-large": "musicgen_large",
    "rwkv6-7b": "rwkv6_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
}

ARCH_NAMES: List[str] = list(_MODULES)


def get(name: str) -> ArchConfig:
    try:
        mod = _MODULES[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}") from None
    return import_module(f".{mod}", __package__).CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {n: get(n) for n in ARCH_NAMES}


__all__ = [
    "ArchConfig",
    "FTSpec",
    "LayerSpec",
    "MoESpec",
    "SSMSpec",
    "ShapeConfig",
    "SHAPES",
    "ARCH_NAMES",
    "get",
    "all_configs",
    "shape_applicable",
]
