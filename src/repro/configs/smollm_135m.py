"""SmolLM-135M (llama arch, tied embeddings)
[hf:HuggingFaceTB/SmolLM-135M; hf].  9 heads -> attention replicated
across the model axis."""

from .base import ArchConfig, FTSpec, LayerSpec

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
    pattern=(LayerSpec("attn", "dense"),),
    ft=FTSpec(C=10.0, R=10.0),
    source="hf:HuggingFaceTB/SmolLM-135M",
)
