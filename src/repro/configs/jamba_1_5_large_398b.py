"""Jamba-1.5-large 398B: Mamba+attention 1:7 interleave, 16-expert top-2
MoE on alternate layers [arXiv:2403.19887; hf].

Block of 8 (repeated 9x = 72 layers): attention at position 4, Mamba
elsewhere; MoE MLP on odd positions.  Hybrid -> long_500k applies (the
9 attention layers decode linearly against their KV cache).
400B-class: bf16 params + 8-bit Adam moments.
"""

from .base import ArchConfig, FTSpec, LayerSpec, MoESpec, SSMSpec

_P = []
for i in range(8):
    mixer = "attn" if i == 4 else "mamba"
    mlp = "moe" if i % 2 == 1 else "dense"
    _P.append(LayerSpec(mixer, mlp))

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    moe=MoESpec(num_experts=16, top_k=2),
    pattern=tuple(_P),
    ssm=SSMSpec(d_state=16, d_conv=4, expand=2),
    subquadratic=True,
    param_dtype="bfloat16",
    optimizer="adamw8bit",
    ft=FTSpec(C=1200.0, R=1200.0),
    source="arXiv:2403.19887",
)
