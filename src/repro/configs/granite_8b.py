"""IBM Granite 8B code model (llama arch) [arXiv:2405.04324; hf]."""

from .base import ArchConfig, FTSpec, LayerSpec

CONFIG = ArchConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    rope_theta=1e7,
    pattern=(LayerSpec("attn", "dense"),),
    ft=FTSpec(C=120.0, R=120.0),
    source="arXiv:2405.04324",
)
