"""Qwen2-0.5B: GQA with QKV bias, tied embeddings [arXiv:2407.10671; hf].

14 heads do not divide the 16-way model axis -> attention replicated
across `model`; MLP/vocab carry the TP.
"""

from .base import ArchConfig, FTSpec, LayerSpec

CONFIG = ArchConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    pattern=(LayerSpec("attn", "dense"),),
    ft=FTSpec(C=20.0, R=20.0),
    source="arXiv:2407.10671",
)
