"""Snowflake Arctic 480B: 128-expert top-2 MoE with a dense residual MLP.

[hf:Snowflake/snowflake-arctic-base; hf].  56 heads do not divide the
16-way model axis -> attention is replicated across `model` (MoE/MLP soak
the TP); noted in DESIGN.md.  400B-class: bf16 params + 8-bit Adam moments.
"""

from .base import ArchConfig, FTSpec, LayerSpec, MoESpec

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    moe=MoESpec(num_experts=128, top_k=2, dense_residual=True),
    pattern=(LayerSpec("attn", "moe"),),
    param_dtype="bfloat16",
    optimizer="adamw8bit",
    ft=FTSpec(C=1200.0, R=1200.0, predictor="paper-accurate"),
    source="hf:Snowflake/snowflake-arctic-base",
)
