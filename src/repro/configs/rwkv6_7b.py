"""RWKV-6 (Finch) 7B: attention-free, data-dependent decay
[arXiv:2404.05892; hf].  Sub-quadratic -> long_500k applies.
64 WKV heads of dim 64."""

from .base import ArchConfig, FTSpec, LayerSpec, SSMSpec

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,   # WKV heads (d_model / 64); attention-free
    num_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    pattern=(LayerSpec("rwkv", "rwkv_cm"),),
    ssm=SSMSpec(rwkv_head_dim=64, decay_lora=64),
    subquadratic=True,
    ft=FTSpec(C=120.0, R=120.0),
    source="arXiv:2404.05892",
)
