"""LLaVA-NeXT (v1.6) Mistral-7B backbone
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

Backbone only; the anyres vision tower is a stub -- input_specs() provides
precomputed patch embeddings (576-token prefix, one anyres tile)."""

from .base import ArchConfig, FTSpec, LayerSpec

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1e6,
    pattern=(LayerSpec("attn", "dense"),),
    frontend="vision_patches",
    frontend_prefix=576,
    ft=FTSpec(C=120.0, R=120.0),
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
