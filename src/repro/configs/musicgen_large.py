"""MusicGen-large: decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Backbone only; the EnCodec/conditioning frontend is a stub -- input_specs()
provides precomputed conditioning frame embeddings (prefix of 64 frames).
kv=32 == num_heads: full MHA.
"""

from .base import ArchConfig, FTSpec, LayerSpec

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    pattern=(LayerSpec("attn", "dense"),),
    frontend="audio_frames",
    frontend_prefix=64,
    ft=FTSpec(C=60.0, R=60.0),
    source="arXiv:2306.05284",
)
