"""Qwen2-72B: GQA with QKV bias [arXiv:2407.10671; hf]."""

from .base import ArchConfig, FTSpec, LayerSpec

CONFIG = ArchConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    pattern=(LayerSpec("attn", "dense"),),
    param_dtype="bfloat16",
    optimizer="adamw8bit",
    ft=FTSpec(C=600.0, R=600.0),
    source="arXiv:2407.10671",
)
