"""Qwen3-30B-A3B: 128-expert top-8 MoE [hf:Qwen/Qwen3-30B-A3B; hf]."""

from .base import ArchConfig, FTSpec, LayerSpec, MoESpec

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    rope_theta=1e6,
    moe=MoESpec(num_experts=128, top_k=8),
    pattern=(LayerSpec("attn", "moe"),),
    ft=FTSpec(C=300.0, R=300.0),
    source="hf:Qwen/Qwen3-30B-A3B",
)
