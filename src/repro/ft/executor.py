"""FaultTolerantExecutor: the paper's checkpointing policy wrapped around a
real (or simulated) training loop.

The executor owns the step loop and decides, between steps:

1. **Periodic checkpointing** at the paper's optimal period
   ``T = sqrt(2 mu C / (1 - r q))`` — recomputed online as the measured
   checkpoint cost ``C`` and the observed predictor quality (r, p) drift;
2. **Proactive actions** on trusted predictions (probability q in {0,1}
   chosen by the closed-form policy): a checkpoint timed to finish at the
   window start (strategies Instant / NoCkptI / WithCkptI), or a
   migration to a spare (Section 3.4, via ElasticManager);
3. **Recovery** from injected faults: downtime D, restore the newest
   durable checkpoint (memory buddy tier first, disk tier as fallback),
   replay the data stream deterministically from the restored step.

Every second of the run is attributed in a :class:`WasteLedger`
(useful / checkpoint / proactive / lost work / downtime / recovery /
migration), so the empirical waste is directly comparable to the paper's
analytic formula — the paper's validation methodology, live on the real
system.

Time is pluggable: ``SimClock`` runs platform-days in milliseconds for
policy tests; ``WallClock`` measures a real CPU training run.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from ..core import periods as P
from ..core.analytic import optimize
from ..core.predictor import OnlinePredictor, estimate_recall_precision
from ..core.waste import Platform, PredictorModel, waste_exact
from .injection import FaultInjector, SimulatedFault
from .retry import FailureKind, RetryPolicy, classify_failure

__all__ = [
    "SimClock",
    "WallClock",
    "WasteLedger",
    "RunReport",
    "FaultTolerantExecutor",
]

#: minimum observations behind an estimated ratio (TP + FP for the
#: precision estimate, TP + FN for recall) before it may influence
#: re-optimization — below this the prior holds
_MIN_PRED_EVIDENCE = 3


class SimClock:
    def __init__(self, t0: float = 0.0):
        self.t = t0

    def now(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


class WallClock:
    def __init__(self):
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0

    def advance(self, dt: float) -> None:  # wall time advances by itself
        pass


@dataclass
class WasteLedger:
    useful: float = 0.0
    ckpt: float = 0.0
    proactive_ckpt: float = 0.0
    lost_work: float = 0.0
    downtime: float = 0.0
    recovery: float = 0.0
    migration: float = 0.0

    def total(self) -> float:
        return (
            self.useful
            + self.ckpt
            + self.proactive_ckpt
            + self.lost_work
            + self.downtime
            + self.recovery
            + self.migration
        )

    def waste(self) -> float:
        t = self.total()
        return 1.0 - self.useful / t if t > 0 else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {
            "useful": self.useful,
            "ckpt": self.ckpt,
            "proactive_ckpt": self.proactive_ckpt,
            "lost_work": self.lost_work,
            "downtime": self.downtime,
            "recovery": self.recovery,
            "migration": self.migration,
            "waste": self.waste(),
        }


@dataclass
class RunReport:
    steps_done: int
    ledger: WasteLedger
    n_faults: int
    n_restores: int
    n_proactive: int
    n_periodic: int
    n_migrations: int
    period_T: float
    q: int
    analytic_waste: float
    c_estimate: float

    def summary(self) -> str:
        l = self.ledger
        return (
            f"steps={self.steps_done} faults={self.n_faults} "
            f"restores={self.n_restores} periodic_ckpts={self.n_periodic} "
            f"proactive={self.n_proactive} migrations={self.n_migrations} "
            f"T={self.period_T:.0f}s q={self.q} "
            f"waste={l.waste():.4f} (analytic {self.analytic_waste:.4f})"
        )


class FaultTolerantExecutor:
    """See module docstring.

    Parameters
    ----------
    step_fn       (state, step:int) -> state.  Raises SimulatedFault via
                  the injector's check or naturally.
    save_state    state -> pytree to checkpoint (e.g. params+opt+step)
    load_state    (state, restored_pytree, step) -> state
    platform      Platform (mu, C prior, D, R, M)
    predictor     OnlinePredictor or None
    pred_model    PredictorModel prior (r, p, lead, window)
    checkpointer  object with .save(step, tree) -> C_block seconds and
                  .durable_step / .wait(); or None for simulated cost
    restore_fn    (step:int) -> pytree, used on recovery (None in pure
                  simulation mode)
    restore_tiers ordered restore sources, each (step:int) -> pytree —
                  e.g. [memory_tier, disk_tier].  A failing tier is
                  retried under the shared retry/backoff classifier
                  (:mod:`repro.ft.retry`), then the next tier is tried,
                  then an *older* checkpointed step — every failed
                  attempt is charged to the ledger's recovery bucket
                  (and the re-lost work to lost_work).  Defaults to
                  ``[restore_fn]``.
    restore_retry RetryPolicy for the restore ladder (injectable sleep
                  for tests; sim-clock time is charged instead of
                  sleeping when ``clock`` is a SimClock)
    injector      FaultInjector or None
    clock         SimClock (simulated costs) or WallClock (measured)
    step_time     simulated seconds per step (SimClock mode)
    strategy      "auto" | "young" | "exact" | "nockpt" | "withckpt" |
                  "migration"
    elastic       ElasticManager or None (required for "migration")
    """

    def __init__(
        self,
        *,
        step_fn: Callable[[Any, int], Any],
        state: Any,
        platform: Platform,
        pred_model: Optional[PredictorModel] = None,
        predictor: Optional[OnlinePredictor] = None,
        checkpointer: Any = None,
        save_state: Callable[[Any], Any] = lambda s: s,
        load_state: Callable[[Any, Any, int], Any] = lambda s, t, k: t,
        restore_fn: Optional[Callable[[int], Any]] = None,
        restore_tiers: Optional[List[Callable[[int], Any]]] = None,
        restore_retry: Optional[RetryPolicy] = None,
        injector: Optional[FaultInjector] = None,
        clock: Optional[Any] = None,
        step_time: float = 1.0,
        strategy: str = "auto",
        elastic: Any = None,
        adapt_period: bool = True,
    ):
        self.step_fn = step_fn
        self.state = state
        self.platform = platform
        self.pred_model = pred_model or PredictorModel(0.0, 1.0)
        self.predictor = predictor
        self.checkpointer = checkpointer
        self.save_state = save_state
        self.load_state = load_state
        self.restore_fn = restore_fn
        if restore_tiers is not None:
            self.restore_tiers = list(restore_tiers)
        else:
            self.restore_tiers = [restore_fn] if restore_fn is not None else []
        self.restore_retry = restore_retry or RetryPolicy()
        self.injector = injector
        self.clock = clock or SimClock()
        self.sim = isinstance(self.clock, SimClock)
        self.step_time = step_time
        self.strategy = strategy
        self.elastic = elastic
        self.adapt_period = adapt_period

        self.ledger = WasteLedger()
        self.c_est = platform.C
        self.n_faults = 0
        self.n_restores = 0
        self.n_proactive = 0
        self.n_periodic = 0
        self.n_migrations = 0
        self.tp_obs = 0
        self.fp_obs = 0
        self.fn_obs = 0

        self._last_ckpt_step = 0
        self._ckpt_history: List[int] = [0]  # steps with a restore point
        self._restore_ctr = 0  # deterministic backoff counter
        self._work_since_ckpt = 0.0
        self._pending: List[Any] = []  # trusted predictions not yet acted on
        self._window_until = -math.inf  # NoCkptI: suppress periodic ckpts
        self._policy = self._compute_policy()

    # ------------------------------------------------------------------ #
    # policy
    # ------------------------------------------------------------------ #
    def _observed_model(self) -> PredictorModel:
        if self.tp_obs + self.fp_obs + self.fn_obs >= 20:
            r, p = estimate_recall_precision(self.tp_obs, self.fp_obs, self.fn_obs)
            # blend with prior to avoid early noise — but each ratio only
            # once its own denominator has evidence: a degenerate 0.0
            # estimate (no predictions observed, or no faults observed)
            # must not swing the re-optimized policy off the prior
            if self.tp_obs + self.fn_obs >= _MIN_PRED_EVIDENCE:
                r = 0.5 * r + 0.5 * self.pred_model.recall
            else:
                r = self.pred_model.recall
            if self.tp_obs + self.fp_obs >= _MIN_PRED_EVIDENCE:
                p = 0.5 * p + 0.5 * self.pred_model.precision
            else:
                p = self.pred_model.precision
            return PredictorModel(r, p, self.pred_model.lead, self.pred_model.window)
        return self.pred_model

    def _compute_policy(self) -> P.OptimalPolicy:
        plat = Platform(
            mu=self.platform.mu,
            C=self.c_est,
            D=self.platform.D,
            R=self.platform.R,
            M=self.platform.M,
        )
        pm = self._observed_model()
        if self.strategy == "young" or self.predictor is None:
            # uncapped Young period (the Section 5 practice; matches sims)
            return optimize("young", plat, pm)
        name = "best" if self.strategy == "auto" else self.strategy
        if name in ("best", "exact", "nockpt", "withckpt", "migration"):
            return optimize(name, plat, pm)
        raise ValueError(self.strategy)

    # ------------------------------------------------------------------ #
    # actions
    # ------------------------------------------------------------------ #
    def _do_checkpoint(self, step: int, proactive: bool) -> None:
        t0 = self.clock.now()
        if self.checkpointer is not None:
            c_block = self.checkpointer.save(step, self.save_state(self.state))
            if self.sim:
                self.clock.advance(self.platform.C)
                cost = self.platform.C
            else:
                cost = c_block
            # EWMA of the measured blocking cost feeds the period formula
            if not self.sim:
                self.c_est = 0.7 * self.c_est + 0.3 * max(c_block, 1e-4)
        else:
            self.clock.advance(self.platform.C)
            cost = self.platform.C
        if proactive:
            self.ledger.proactive_ckpt += cost
            self.n_proactive += 1
        else:
            self.ledger.ckpt += cost
            self.n_periodic += 1
        self._last_ckpt_step = step
        if step not in self._ckpt_history:
            self._ckpt_history.append(step)
        self._work_since_ckpt = 0.0
        if self.adapt_period:
            self._policy = self._compute_policy()

    def _do_migration(self, step: int, pred) -> None:
        cost = self.platform.M or self.c_est
        if self.elastic is not None:
            self.elastic.migrate(reason="prediction")
        if self.sim:
            self.clock.advance(cost)
        self.ledger.migration += cost
        self.n_migrations += 1
        if pred.fault_time is not None and self.injector is not None:
            self.injector.cancel(pred.fault_time)

    def _handle_fault(self, step: int, fault: SimulatedFault) -> int:
        self.n_faults += 1
        if fault.predicted:
            self.tp_obs += 1
        else:
            self.fn_obs += 1
        # lost work: everything since the last durable checkpoint
        self.ledger.lost_work += self._work_since_ckpt
        self._work_since_ckpt = 0.0
        if self.sim:
            self.clock.advance(self.platform.D)
        self.ledger.downtime += self.platform.D
        t0 = self.clock.now()
        restored_step = self._last_ckpt_step
        if self.restore_tiers:
            if self.checkpointer is not None and hasattr(
                self.checkpointer, "wait"
            ):
                try:
                    self.checkpointer.wait()
                except Exception:
                    pass
            tree, restored_step = self._restore_with_fallback(restored_step)
            self.state = self.load_state(self.state, tree, restored_step)
        if self.sim:
            self.clock.advance(self.platform.R)
            self.ledger.recovery += self.platform.R
        else:
            self.ledger.recovery += self.clock.now() - t0 + self.platform.D * 0
        self.n_restores += 1
        return restored_step

    def _restore_with_fallback(self, step: int) -> Tuple[Any, int]:
        """Restore ``step`` through the tier ladder, newest-first.

        Per candidate step: every tier in order, each with
        ``restore_retry.max_attempts`` classified/backed-off attempts
        (FATAL skips straight to the next tier).  A failing attempt
        costs a restore — ``platform.R`` on the sim clock, charged to
        the recovery bucket (wall clocks measure it for real).  When a
        candidate step is abandoned entirely, the work between it and
        the next-older restore point is re-lost.  Raises the last error
        if nothing restores."""
        candidates = sorted(
            {s for s in self._ckpt_history if s <= step}, reverse=True
        ) or [step]
        pol = self.restore_retry
        last_err: Optional[Exception] = None
        for ci, cand in enumerate(candidates):
            if ci:
                # falling back to an older restore point re-loses the
                # work in between (paper: the recovery term grows)
                self.ledger.lost_work += (
                    (candidates[ci - 1] - cand) * self.step_time
                )
            for tier in self.restore_tiers:
                for attempt in range(pol.max_attempts):
                    try:
                        return tier(cand), cand
                    except Exception as e:  # classified below
                        last_err = e
                        self._restore_ctr += 1
                        # the failed attempt consumed a restore's time
                        if self.sim:
                            self.clock.advance(self.platform.R)
                            self.ledger.recovery += self.platform.R
                        if classify_failure(e) is FailureKind.FATAL:
                            break  # this tier cannot serve this step
                        dt = pol.backoff(attempt, self._restore_ctr)
                        if self.sim:
                            self.clock.advance(dt)
                            self.ledger.recovery += dt
                        else:
                            pol.sleep(dt)
        if last_err is not None:
            raise last_err
        raise IOError(f"no restore tier could serve step {step}")

    # ------------------------------------------------------------------ #
    # main loop
    # ------------------------------------------------------------------ #
    def run(self, n_steps: int, start_step: int = 0) -> RunReport:
        step = start_step
        q = self._policy.q
        while step < n_steps:
            now = self.clock.now()

            # 1) ingest predictions
            if self.predictor is not None and q:
                for ev in self.predictor.poll(now):
                    self._pending.append(ev)

            # 2) proactive actions due?  act when now >= t0 - C (as late as
            #    possible, paper Figure 1(a))
            acted = False
            still = []
            for ev in self._pending:
                act_at = ev.t0 - (
                    self.platform.M
                    if self.strategy == "migration"
                    else self.c_est
                )
                if now >= act_at:
                    if ev.t0 + ev.window < now:
                        # stale (e.g. we were in recovery): drop; count FP if
                        # it never materialized
                        if ev.fault_time is None:
                            self.fp_obs += 1
                        continue
                    if self.strategy == "migration":
                        self._do_migration(step, ev)
                    else:
                        self._do_checkpoint(step, proactive=True)
                        if self._policy.strategy in ("nockpt", "withckpt"):
                            self._window_until = ev.t0 + ev.window
                    if ev.fault_time is None:
                        self.fp_obs += 1
                    acted = True
                else:
                    still.append(ev)
            self._pending = still

            # 3) periodic checkpoint due? (suppressed inside a NoCkptI window)
            work_target = max(self._policy.T_R - self.c_est, self.step_time)
            in_window = now < self._window_until
            t_p = self._policy.T_P
            if in_window and self._policy.strategy == "withckpt" and t_p:
                if self._work_since_ckpt >= max(t_p - self.c_est, self.step_time):
                    self._do_checkpoint(step, proactive=True)
            elif not in_window and self._work_since_ckpt >= work_target:
                self._do_checkpoint(step, proactive=False)

            # 4) one training step
            t0 = self.clock.now()
            try:
                if self.injector is not None:
                    self.injector.check(t0)
                self.state = self.step_fn(self.state, step)
                if self.sim:
                    self.clock.advance(self.step_time)
                    dt = self.step_time
                else:
                    dt = self.clock.now() - t0
                self.ledger.useful += dt
                self._work_since_ckpt += dt
                step += 1
            except SimulatedFault as f:
                if self.sim and f.time > t0:
                    # part of the step ran before the fault
                    ran = min(self.step_time, max(f.time - t0, 0.0))
                    self.clock.advance(ran)
                    self.ledger.lost_work += ran
                step = self._handle_fault(step, f)

        if self.checkpointer is not None and hasattr(self.checkpointer, "wait"):
            self.checkpointer.wait()

        pm = self._observed_model()
        analytic = waste_exact(
            self._policy.T_R,
            q,
            self.c_est,
            self.platform.D,
            self.platform.R,
            self.platform.mu,
            pm.recall,
            pm.precision,
        )
        return RunReport(
            steps_done=step,
            ledger=self.ledger,
            n_faults=self.n_faults,
            n_restores=self.n_restores,
            n_proactive=self.n_proactive,
            n_periodic=self.n_periodic,
            n_migrations=self.n_migrations,
            period_T=self._policy.T_R,
            q=q,
            analytic_waste=float(analytic),
            c_estimate=self.c_est,
        )
