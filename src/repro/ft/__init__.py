"""Fault-tolerance runtime: the paper's prediction-aware checkpointing
policy driving a real training loop, plus fault injection, elastic
migration, straggler mitigation, and the resumable campaign runner that
applies the same checkpointing calculus to the sweeps themselves."""

from .executor import FaultTolerantExecutor, RunReport, SimClock, WallClock, WasteLedger
from .injection import (
    CampaignKilled,
    ChaosInjector,
    FaultInjector,
    SimulatedFault,
    SyntheticDeviceLoss,
    SyntheticJaxFailure,
    SyntheticOOM,
)
from .elastic import ElasticManager, StragglerDetector
from .retry import FailureKind, RetryPolicy, classify_failure
from .campaign import CampaignConfig, CampaignRunner, run_campaign

__all__ = [
    "FaultTolerantExecutor",
    "RunReport",
    "SimClock",
    "WallClock",
    "WasteLedger",
    "FaultInjector",
    "SimulatedFault",
    "CampaignKilled",
    "ChaosInjector",
    "SyntheticOOM",
    "SyntheticDeviceLoss",
    "SyntheticJaxFailure",
    "FailureKind",
    "RetryPolicy",
    "classify_failure",
    "CampaignConfig",
    "CampaignRunner",
    "run_campaign",
]
