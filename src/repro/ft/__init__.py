"""Fault-tolerance runtime: the paper's prediction-aware checkpointing
policy driving a real training loop, plus fault injection, elastic
migration and straggler mitigation."""

from .executor import FaultTolerantExecutor, RunReport, SimClock, WallClock, WasteLedger
from .injection import FaultInjector, SimulatedFault
from .elastic import ElasticManager, StragglerDetector

__all__ = [
    "FaultTolerantExecutor",
    "RunReport",
    "SimClock",
    "WallClock",
    "WasteLedger",
    "FaultInjector",
    "SimulatedFault",
    "ElasticManager",
    "StragglerDetector",
]
