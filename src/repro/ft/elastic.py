"""Elastic scaling, preventive migration (paper Section 3.4) and straggler
mitigation.

On a real fleet this module talks to the cluster scheduler: it keeps a
spare-node pool, swaps a predicted-to-fail (or persistently slow) node for
a spare, and — when no spare exists — shrinks the mesh and re-shards from
the newest checkpoint (CheckpointStore.restore supports re-sharding).
Here the node set is logical; what is real is the *decision logic* and
its costs, which feed the paper's migration model (Equation (3), cost M).

Straggler mitigation reuses the paper's calculus: a straggler detector is
a "slowness predictor" with its own recall/precision; migrating a slow
node is priced exactly like migrating a predicted-faulty one.
"""

from __future__ import annotations

import statistics
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Set

__all__ = ["ElasticManager", "StragglerDetector"]


@dataclass
class ElasticManager:
    n_nodes: int
    n_spares: int = 2
    migration_cost: float = 300.0  # M, seconds

    def __post_init__(self):
        self.active: Set[int] = set(range(self.n_nodes))
        self.spares: List[int] = list(
            range(self.n_nodes, self.n_nodes + self.n_spares)
        )
        self.retired: Set[int] = set()
        self.events: List[dict] = []

    # ------------------------------------------------------------------ #
    def migrate(self, node: Optional[int] = None, reason: str = "prediction") -> dict:
        """Swap ``node`` (or an arbitrary active node) for a spare.

        Returns the event record (incl. whether a shrink was needed)."""
        if node is None:
            node = next(iter(self.active))
        self.active.discard(node)
        self.retired.add(node)
        if self.spares:
            repl = self.spares.pop(0)
            self.active.add(repl)
            ev = {
                "kind": "migration",
                "from": node,
                "to": repl,
                "reason": reason,
                "cost": self.migration_cost,
                "shrunk": False,
            }
        else:
            ev = {
                "kind": "shrink",
                "from": node,
                "to": None,
                "reason": reason,
                # shrink = restore latest checkpoint on a smaller mesh
                "cost": self.migration_cost,
                "shrunk": True,
            }
        self.events.append(ev)
        return ev

    def lose_node(self, node: int) -> dict:
        """Unpredicted hard failure of ``node``."""
        return self.migrate(node, reason="failure")

    @property
    def world_size(self) -> int:
        return len(self.active)


class StragglerDetector:
    """Flags ranks whose step times are persistent outliers.

    A rank is a straggler when its trailing-window median exceeds
    ``threshold`` x the cross-rank median for ``patience`` consecutive
    windows.  The detector's empirical recall/precision can be fed to the
    paper's policy to decide whether acting on it is worthwhile
    (ElasticManager.migration_cost as M)."""

    def __init__(
        self,
        n_ranks: int,
        window: int = 16,
        threshold: float = 1.5,
        patience: int = 3,
    ):
        self.n_ranks = n_ranks
        self.window = window
        self.threshold = threshold
        self.patience = patience
        self._hist: Dict[int, Deque[float]] = defaultdict(
            lambda: deque(maxlen=window)
        )
        self._strikes: Dict[int, int] = defaultdict(int)

    def record(self, rank: int, step_time: float) -> None:
        self._hist[rank].append(step_time)

    def check(self) -> List[int]:
        """Returns ranks currently flagged as stragglers."""
        medians = {
            r: statistics.median(h)
            for r, h in self._hist.items()
            if len(h) >= self.window // 2
        }
        if len(medians) < 2:
            return []
        global_med = statistics.median(medians.values())
        flagged = []
        for r, m in medians.items():
            if m > self.threshold * global_med:
                self._strikes[r] += 1
                if self._strikes[r] >= self.patience:
                    flagged.append(r)
            else:
                self._strikes[r] = 0
        return flagged
