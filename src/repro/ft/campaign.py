"""Resumable, self-healing campaign runner: the paper applied to itself.

A million-lane fused sweep (:func:`repro.experiments.run_grid`) is a
long-running job on a fallible platform, so it gets the same treatment
the paper gives HPC applications: :class:`CampaignRunner` owns the fused
chunk loop and periodically snapshots the *tiny* durable state — the
per-cell :class:`~repro.core.jax_sim.CellSums` accumulator matrix, the
lane cursor, and the current chunk width — through the repo's own
:class:`~repro.checkpoint.CheckpointStore` / :class:`~repro.checkpoint.
AsyncCheckpointer`.  Counter-based RNG streams make the snapshot O(cells):
lane traces are a pure function of ``(grid.seed, lane)``, so resume
replays *nothing* — it rebuilds the :class:`~repro.experiments.runner.
FusedLayout` from the grid and continues at the cursor, and the resumed
run's :class:`~repro.experiments.grid.SweepResult` is bit-identical to
the uninterrupted run's.

The snapshot period is chosen online by the paper's own formula:
:func:`repro.core.optimize` ("young") on a :class:`~repro.core.waste.
Platform` whose ``C`` is the *measured* snapshot cost (EWMA) and whose
``mu`` is the configured platform MTBF — dogfooding Equation (1) on the
simulator itself.  ``ckpt_period`` overrides it (0 = snapshot every
chunk).

Dispatch failures are classified at chunk boundaries
(:func:`repro.ft.retry.classify_failure`) and recovered without losing
the campaign:

* **OOM** — halve ``chunk_lanes`` (results are chunk-size invariant)
  and retry under jittered exponential backoff;
* **device loss** — rebuild the dispatch on the surviving devices
  (results are device-count invariant, so this is bit-exact);
* **persistent engine failure** — once the retry budget is exhausted,
  degrade ``engine="jax"`` to the NumPy ``"batch"`` engine for the rest
  of the campaign (same streams, host replay) and record the
  degradation in the result metadata;
* **process kill** — nothing to do: the next incarnation resumes from
  the newest valid snapshot (:meth:`CheckpointStore.restore_latest`
  skips torn/corrupt ones).

Chaos testing hooks in at the same boundary: a :class:`~repro.ft.
injection.ChaosInjector` fires deterministic synthetic kills / OOMs /
device losses so CI exercises every row of that matrix.
"""

from __future__ import annotations

import hashlib
import json
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from ..checkpoint.async_ckpt import AsyncCheckpointer
from ..checkpoint.store import CheckpointStore
from ..core.analytic import optimize
from ..core.batch_sim import simulate_batch
from ..core.engine import EngineConfig, resolve_engine_config
from ..core.waste import Platform
from ..experiments.grid import CellResult, GridSpec, SweepResult
from ..experiments.runner import (
    _LANE_FIELDS,
    _lane_arrays,
    _stats_cell_result,
    FusedLayout,
    build_fused_layout,
)
from .injection import ChaosInjector
from .retry import FailureKind, RetryPolicy, classify_failure

__all__ = ["CampaignConfig", "CampaignRunner", "run_campaign"]

#: RNG namespace tag of the campaign's per-chunk host-mode trust coins
#: (device trace mode draws trust from the lanes' own counter streams and
#: never touches this): seeds ``[grid.seed, n_groups, _RNG_TAG, lane_lo]``
#: are disjoint from every run_grid seed family by length and tag.
_RNG_TAG = 0x0C47

#: number of int64 slots in the durable cursor record
_CURSOR_FIELDS = 5  # lanes_done, chunk_lanes, chunk_index, incarnation, degraded


@dataclass
class CampaignConfig:
    """Durability/recovery knobs of a :class:`CampaignRunner`.

    ckpt_dir         checkpoint store root for the campaign snapshots.
    mtbf             assumed MTBF (seconds) of the platform *running the
                     campaign* — the ``mu`` of the snapshot-period
                     formula, not of the simulated platforms.
    ckpt_period      snapshot period override (seconds); ``0`` snapshots
                     at every chunk boundary, ``None`` lets
                     ``repro.core.optimize("young")`` choose from the
                     measured snapshot cost and ``mtbf``.
    restore_cost     assumed R (seconds) of a campaign resume, for the
                     period formula.
    save_cost_prior  prior C (seconds) before the first measured save.
    keep             committed snapshots retained (older ones GC'd).
    async_snapshots  drain snapshots on a background thread
                     (:class:`AsyncCheckpointer`); the blocking cost is
                     then just the host copy, which is what feeds C.
    retry            shared :class:`RetryPolicy` for dispatch failures.
    min_chunk_lanes  floor of the OOM chunk-halving ladder.
    chaos            optional :class:`ChaosInjector` fired at every
                     chunk boundary (tests/CI).
    """

    ckpt_dir: str
    mtbf: float = 3600.0
    ckpt_period: Optional[float] = None
    restore_cost: float = 1.0
    save_cost_prior: float = 0.05
    keep: int = 3
    async_snapshots: bool = True
    retry: RetryPolicy = field(default_factory=RetryPolicy)
    min_chunk_lanes: int = 8
    chaos: Optional[ChaosInjector] = None


def _grid_fingerprint(grid: GridSpec, trace_mode: str, collect: str) -> str:
    """Identity of (grid, trace source, result layout): a snapshot may
    only resume a campaign that would recompute the same lanes."""
    text = repr((grid, trace_mode, collect))
    return hashlib.sha256(text.encode()).hexdigest()[:16]


def _jsonable(obj: Any) -> Any:
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    return obj


class CampaignRunner:
    """Killable, resumable fused sweep (see module docstring).

    Parameters
    ----------
    grid      the :class:`GridSpec` to run.
    campaign  a :class:`CampaignConfig` (durability/recovery knobs).
    config    an :class:`~repro.core.engine.EngineConfig`; must select
              ``engine="jax"`` (the degradation *target* is "batch").
              ``chunk_lanes`` is the campaign's snapshot/recovery
              granularity: "auto" picks the engine's measured-optimal
              chunk for the device set, ``None`` runs one chunk.
    """

    def __init__(
        self,
        grid: GridSpec,
        campaign: CampaignConfig,
        config: Optional[EngineConfig] = None,
    ):
        cfg = resolve_engine_config(config, "CampaignRunner")
        cfg.validate()
        if cfg.engine != "jax":
            raise ValueError(
                "CampaignRunner requires engine='jax' (the batch engine "
                "is its degradation target, not a starting point)"
            )
        if cfg.dispatch not in (None, "fused"):
            raise ValueError("CampaignRunner only runs dispatch='fused'")
        self.grid = grid
        self.cfg = cfg
        self.camp = campaign
        self.layout: FusedLayout = build_fused_layout(grid, cfg.trace_mode)
        self._fingerprint = _grid_fingerprint(
            grid, cfg.trace_mode, cfg.collect
        )

        from ..core.jax_sim import _resolve_devices, default_chunk_lanes

        self._devices = list(_resolve_devices(cfg.devices, cfg.mesh))
        if cfg.chunk_lanes == "auto":
            chunk = default_chunk_lanes(
                self._devices, trace_mode=cfg.trace_mode
            )
        elif cfg.chunk_lanes is None:
            chunk = max(1, self.layout.n_lanes)
        else:
            chunk = int(cfg.chunk_lanes)
        self._chunk_lanes0 = max(1, chunk)

        self.store = CheckpointStore(campaign.ckpt_dir, codec="raw")
        self._async: Optional[AsyncCheckpointer] = (
            AsyncCheckpointer(self.store, keep=campaign.keep)
            if campaign.async_snapshots
            else None
        )

        n_cells = len(self.layout.cell_order)
        self._spec = (
            self.layout.concat_spec() if cfg.trace_mode == "device" else None
        )
        self._host_traces_cache = self.layout.traces  # device mode: lazy
        # mutable campaign state (the durable part of it is snapshotted)
        self._sums = np.zeros((n_cells, 12), np.float64)
        self._lane_parts: List[Dict[str, np.ndarray]] = []
        self._lanes_done = 0
        self._chunk_lanes = self._chunk_lanes0
        self._chunk_index = 0
        self._incarnation = 0
        self._degraded = False
        self._wall_prev = 0.0
        self._events: List[Dict] = []
        self._n_snapshots = 0
        self._c_est = campaign.save_cost_prior
        self._chunk_cost = 0.0  # EWMA of per-chunk wall cost
        self._wall_since_snap = 0.0
        self._snap_period = self._compute_period()

    # ------------------------------------------------------------------ #
    # snapshot period: the paper's formula on the campaign itself
    # ------------------------------------------------------------------ #
    def _compute_period(self) -> float:
        if self.camp.ckpt_period is not None:
            return float(self.camp.ckpt_period)
        plat = Platform(
            mu=self.camp.mtbf,
            C=max(self._c_est, 1e-4),
            D=0.0,
            R=self.camp.restore_cost,
        )
        # uncapped Young period from the measured snapshot cost: the
        # q=0 closed form — campaign faults are unpredicted kills
        return float(optimize("young", plat).T_R)

    # ------------------------------------------------------------------ #
    # durable state
    # ------------------------------------------------------------------ #
    def _state_tree(self) -> Dict[str, np.ndarray]:
        meta = {
            "fingerprint": self._fingerprint,
            "events": _jsonable(self._events),
            "n_snapshots": self._n_snapshots,
            "c_est": self._c_est,
        }
        blob = np.frombuffer(
            json.dumps(meta).encode("utf-8"), dtype=np.uint8
        ).copy()
        cursor = np.array(
            [
                self._lanes_done,
                self._chunk_lanes,
                self._chunk_index,
                self._incarnation,
                int(self._degraded),
            ],
            np.int64,
        )
        wall = np.array(
            [self._wall_prev + (time.monotonic() - self._t_start)], np.float64
        )
        # copies: the async drain serializes on a background thread while
        # the chunk loop keeps mutating the live accumulators
        tree = {
            "sums": self._sums.copy(),
            "cursor": cursor,
            "wall": wall,
            "meta": blob,
        }
        if self.cfg.collect == "lanes" and self._lane_parts:
            cat = {
                k: np.concatenate([p[k] for p in self._lane_parts])
                for k in _LANE_FIELDS
            }
            for k, v in cat.items():
                tree[f"lane/{k}"] = np.asarray(v).copy()
        return tree

    def _load_state(self, host: Dict[str, np.ndarray]) -> None:
        meta = json.loads(bytes(host["meta"].tobytes()).decode("utf-8"))
        if meta["fingerprint"] != self._fingerprint:
            raise ValueError(
                "refusing to resume: snapshot belongs to a different "
                f"campaign (fingerprint {meta['fingerprint']} != "
                f"{self._fingerprint})"
            )
        cur = np.asarray(host["cursor"], np.int64)
        self._lanes_done = int(cur[0])
        self._chunk_lanes = int(cur[1])
        self._chunk_index = int(cur[2])
        self._incarnation = int(cur[3]) + 1  # this process is the next life
        self._degraded = bool(cur[4])
        sums = np.asarray(host["sums"], np.float64)
        if sums.shape != self._sums.shape:
            raise ValueError(
                "refusing to resume: snapshot accumulator has shape "
                f"{sums.shape}, this build expects {self._sums.shape} "
                "(snapshot predates the two-level/silent stats columns "
                "— rerun the campaign with resume=False)"
            )
        self._sums = sums.copy()
        self._wall_prev = float(np.asarray(host["wall"])[0])
        self._events = list(meta["events"])
        self._n_snapshots = int(meta["n_snapshots"])
        self._c_est = float(meta["c_est"])
        self._lane_parts = []
        if self.cfg.collect == "lanes":
            if self._lanes_done and f"lane/waste" not in host:
                raise ValueError(
                    "snapshot has no lane arrays but collect='lanes'"
                )
            if f"lane/waste" in host:
                self._lane_parts = [
                    {k: np.asarray(host[f"lane/{k}"]) for k in _LANE_FIELDS}
                ]

    def _snapshot(self) -> None:
        tree = self._state_tree()
        step = self._lanes_done
        if self._async is not None:
            c_block = self._async.save(step, tree)
            cost = max(float(c_block), 1e-5)
        else:
            t0 = time.monotonic()
            self.store.save(step, tree)
            self.store.gc(keep=self.camp.keep)
            cost = max(time.monotonic() - t0, 1e-5)
        self._c_est = 0.7 * self._c_est + 0.3 * cost
        self._n_snapshots += 1
        self._wall_since_snap = 0.0
        self._snap_period = self._compute_period()

    def _try_resume(self) -> bool:
        found = self.store.restore_latest()
        if found is None:
            return False
        step, host = found
        self._load_state(host)
        self._events.append(
            {
                "kind": "resume",
                "lanes_done": self._lanes_done,
                "chunk": self._chunk_index,
                "incarnation": self._incarnation,
            }
        )
        return True

    # ------------------------------------------------------------------ #
    # chunk dispatch + recovery
    # ------------------------------------------------------------------ #
    def _host_traces(self):
        if self._host_traces_cache is None:
            self._host_traces_cache = self.layout.host_traces()
        return self._host_traces_cache

    def _chunk_rng(self, lo: int) -> np.random.Generator:
        return np.random.default_rng(
            [self.grid.seed, self.layout.n_groups, _RNG_TAG, lo]
        )

    def _dispatch_jax(self, lo: int, hi: int):
        from ..core.jax_sim import simulate_batch_jax

        lay = self.layout
        rows = np.arange(lo, hi)
        if self._spec is not None:
            return simulate_batch_jax(
                lay.work_c, lay.plats_c, lay.strats_c,
                self._spec.take(rows),
                chunk=None, devices=self._devices,
                collect=self.cfg.collect,
            )
        return simulate_batch_jax(
            lay.work_c, lay.plats_c, lay.strats_c,
            lay.traces.take(rows),
            rng=self._chunk_rng(lo),
            chunk=None, devices=self._devices,
            cell_index=lay.cidx[lo:hi], collect=self.cfg.collect,
        )

    def _dispatch_batch(self, lo: int, hi: int):
        lay = self.layout
        rows = np.arange(lo, hi)
        cidx_sub = lay.cidx[lo:hi]
        return simulate_batch(
            lay.work_c[cidx_sub],
            [lay.plats_c[k] for k in cidx_sub],
            [lay.strats_c[k] for k in cidx_sub],
            self._host_traces().take(rows),
            rng=self._chunk_rng(lo),
        )

    def _lanes_to_matrix(self, res, cidx_sub: np.ndarray) -> np.ndarray:
        """Host-side per-cell reduction of a degraded (batch-engine)
        chunk: the same 12 CellSums columns, np.add.at over lanes."""
        m = np.zeros_like(self._sums)
        zeros = np.zeros(cidx_sub.shape[0])
        nd = res.n_disk_recoveries
        nv = res.n_detections
        cols = (
            np.ones(cidx_sub.shape[0]),
            res.makespan, res.makespan ** 2,
            res.waste, res.waste ** 2,
            res.n_faults, res.n_proactive_ckpts, res.n_regular_ckpts,
            res.n_migrations, res.trace_exhausted,
            zeros if nd is None else nd,
            zeros if nv is None else nv,
        )
        for j, v in enumerate(cols):
            np.add.at(m[:, j], cidx_sub, np.asarray(v, np.float64))
        return m

    def _accumulate(self, out, lo: int, hi: int) -> None:
        cidx_sub = self.layout.cidx[lo:hi]
        if self.cfg.collect == "stats":
            if self._degraded:
                self._sums += self._lanes_to_matrix(out, cidx_sub)
            else:
                self._sums += out.as_matrix()
        else:
            self._lane_parts.append(_lane_arrays(out))

    def _run_chunk(self, lo: int) -> int:
        """Dispatch one chunk with chaos, classification and recovery;
        returns the new cursor (``hi`` of the committed chunk)."""
        camp, chaos = self.camp, self.camp.chaos
        attempt = 0
        while True:
            hi = min(lo + self._chunk_lanes, self.layout.n_lanes)
            engine = "batch" if self._degraded else "jax"
            try:
                if chaos is not None:
                    chaos.at_chunk_boundary(
                        self._chunk_index,
                        incarnation=self._incarnation,
                        attempt=attempt,
                        engine=engine,
                    )
                out = (
                    self._dispatch_batch(lo, hi)
                    if self._degraded
                    else self._dispatch_jax(lo, hi)
                )
            except Exception as exc:
                kind = classify_failure(exc)
                if kind is FailureKind.FATAL:
                    raise
                self._events.append(
                    {
                        "kind": kind.value,
                        "chunk": self._chunk_index,
                        "attempt": attempt,
                        "error": f"{type(exc).__name__}: {exc}"[:200],
                    }
                )
                attempt += 1
                ctr = self._chunk_index * 64 + attempt
                if attempt < camp.retry.max_attempts:
                    if kind is FailureKind.OOM and (
                        self._chunk_lanes > camp.min_chunk_lanes
                    ):
                        # allocation pressure: shrink the resident-lane
                        # footprint (results are chunk-size invariant)
                        self._chunk_lanes = max(
                            camp.min_chunk_lanes, self._chunk_lanes // 2
                        )
                        self._events.append(
                            {
                                "kind": "chunk_halved",
                                "chunk": self._chunk_index,
                                "chunk_lanes": self._chunk_lanes,
                            }
                        )
                    elif kind is FailureKind.DEVICE_LOSS and (
                        len(self._devices) > 1
                    ):
                        n_lost = min(
                            int(getattr(exc, "n_lost", 1)),
                            len(self._devices) - 1,
                        )
                        self._devices = self._devices[
                            : len(self._devices) - n_lost
                        ]
                        self._events.append(
                            {
                                "kind": "devices_shrunk",
                                "chunk": self._chunk_index,
                                "n_devices": len(self._devices),
                            }
                        )
                    camp.retry.pause(attempt - 1, ctr)
                    continue
                # retry budget exhausted: graceful degradation
                if not self._degraded:
                    self._degraded = True
                    attempt = 0
                    self._events.append(
                        {
                            "kind": "engine_degraded",
                            "chunk": self._chunk_index,
                            "from": "jax",
                            "to": "batch",
                        }
                    )
                    continue
                raise
            self._accumulate(out, lo, hi)
            return hi

    # ------------------------------------------------------------------ #
    def run(self, resume: Any = "auto") -> SweepResult:
        """Run (or resume) the campaign to completion.

        ``resume`` — "auto": continue from the newest valid snapshot in
        ``ckpt_dir`` if one exists; True: require one; False: start
        fresh (existing snapshots are ignored and then overwritten)."""
        self._t_start = time.monotonic()
        if resume in ("auto", True):
            resumed = self._try_resume()
            if resume is True and not resumed:
                raise FileNotFoundError(
                    f"no resumable snapshot in {self.camp.ckpt_dir}"
                )
        n_lanes = self.layout.n_lanes
        while self._lanes_done < n_lanes:
            t0 = time.monotonic()
            hi = self._run_chunk(self._lanes_done)
            self._lanes_done = hi
            self._chunk_index += 1
            dt = time.monotonic() - t0
            self._chunk_cost = (
                dt if self._chunk_cost == 0.0
                else 0.7 * self._chunk_cost + 0.3 * dt
            )
            self._wall_since_snap += dt
            # snapshot when the accumulated at-risk wall time reaches the
            # optimize()-chosen period (always at period 0)
            if (
                self._lanes_done >= n_lanes
                or self._snap_period <= 0.0
                or self._wall_since_snap + 0.5 * self._chunk_cost
                >= self._snap_period
            ):
                self._snapshot()
        if self._async is not None:
            self._async.wait()  # surface drain errors; final is durable
        return self._result()

    # ------------------------------------------------------------------ #
    def _result(self) -> SweepResult:
        from ..core.jax_sim import CellSums

        lay = self.layout
        cells: List[Optional[CellResult]] = [None] * len(self.grid.cells)
        if self.cfg.collect == "stats":
            sums = CellSums.from_matrix(self._sums)
            for k, ci in enumerate(lay.cell_order):
                cells[ci] = _stats_cell_result(self.grid.cells[ci], sums, k)
        else:
            lanes = {
                k: np.concatenate([p[k] for p in self._lane_parts])
                for k in _LANE_FIELDS
            }
            for k, ci in enumerate(lay.cell_order):
                sl = slice(int(lay.offs[k]), int(lay.offs[k + 1]))
                cells[ci] = CellResult(
                    cell=self.grid.cells[ci],
                    waste=lanes["waste"][sl],
                    makespan=lanes["makespan"][sl],
                    n_faults=lanes["n_faults"][sl],
                    n_proactive_ckpts=lanes["n_proactive_ckpts"][sl],
                    n_regular_ckpts=lanes["n_regular_ckpts"][sl],
                    n_migrations=lanes["n_migrations"][sl],
                    n_exhausted=int(
                        np.count_nonzero(lanes["trace_exhausted"][sl])
                    ),
                )
        wall = self._wall_prev + (time.monotonic() - self._t_start)
        meta = {
            "campaign": _jsonable(
                {
                    "ckpt_dir": self.camp.ckpt_dir,
                    "incarnation": self._incarnation,
                    "n_snapshots": self._n_snapshots,
                    "snapshot_period_s": self._snap_period,
                    "snapshot_cost_est_s": self._c_est,
                    "chunk_lanes_final": self._chunk_lanes,
                    "n_devices_final": len(self._devices),
                    "engine_degraded": self._degraded,
                    "events": self._events,
                }
            )
        }
        return SweepResult(
            grid=self.grid, cells=cells,
            engine="batch" if self._degraded else "jax",
            wall_time_s=wall, dispatch="fused", collect=self.cfg.collect,
            meta=meta,
        )


def run_campaign(
    grid: GridSpec,
    campaign: CampaignConfig,
    config: Optional[EngineConfig] = None,
    resume: Any = "auto",
) -> SweepResult:
    """One-call convenience: build a :class:`CampaignRunner` and run it."""
    return CampaignRunner(grid, campaign, config).run(resume=resume)
