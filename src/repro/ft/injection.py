"""Fault injection: executor-level simulated faults and campaign-level
chaos.

Real fleet faults land as SIGTERMs / slice-health events between or during
steps; here they surface as :class:`SimulatedFault` raised at step
boundaries when the (simulated or wall) clock crosses a fault time from an
:class:`EventTrace` — the same trace generator the paper's simulator uses,
so executor behaviour is directly comparable to the analytic model.

:class:`ChaosInjector` is the campaign-level counterpart: it fires
process kills, synthetic OOMs, device losses and persistent engine
failures at *chunk boundaries* of a :class:`~repro.ft.campaign.
CampaignRunner` sweep, from the repo's deterministic counter-based RNG
(:func:`repro.core.events.splitmix64`) — so every chaos schedule is
replayable from its seed and the whole recovery matrix is exercised in
tests and CI rather than claimed.  The synthetic exceptions carry the
same message fragments the XLA runtime uses, so they route through the
production :func:`repro.ft.retry.classify_failure` classifier.
"""

from __future__ import annotations

import os
import signal
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Set, Tuple

import numpy as np

from ..core.events import EventTrace, splitmix64, uniform24

__all__ = [
    "SimulatedFault",
    "FaultInjector",
    "CampaignKilled",
    "SyntheticOOM",
    "SyntheticDeviceLoss",
    "SyntheticJaxFailure",
    "ChaosInjector",
]


class SimulatedFault(RuntimeError):
    def __init__(self, time: float, predicted: bool):
        super().__init__(f"injected fault at t={time:.1f}s (predicted={predicted})")
        self.time = time
        self.predicted = predicted


class FaultInjector:
    """Raises when execution crosses the next fault time."""

    def __init__(self, trace: EventTrace, cancelled: Optional[set] = None):
        self.fault_times: List[float] = [f.time for f in trace.faults]
        self.predicted = [f.predicted for f in trace.faults]
        self._i = 0
        self.cancelled = cancelled if cancelled is not None else set()

    def cancel(self, fault_time: float) -> None:
        """Migration vacated the node: this fault no longer hits us."""
        self.cancelled.add(fault_time)

    def peek(self) -> Optional[float]:
        while self._i < len(self.fault_times) and (
            self.fault_times[self._i] in self.cancelled
        ):
            self._i += 1
        if self._i >= len(self.fault_times):
            return None
        return self.fault_times[self._i]

    def check(self, now: float) -> None:
        """Raise if a fault occurred at or before ``now``."""
        nxt = self.peek()
        if nxt is not None and nxt <= now:
            predicted = self.predicted[self._i]
            self._i += 1
            raise SimulatedFault(nxt, predicted)


# ---------------------------------------------------------------------- #
# campaign-level chaos
# ---------------------------------------------------------------------- #
class CampaignKilled(BaseException):
    """Process death injected at a chunk boundary (``kill_mode="raise"``).

    Deliberately a :class:`BaseException`: recovery code that catches
    ``Exception`` (the retry classifier) must NOT be able to swallow a
    simulated process death — only the test harness catches it, exactly
    as only the OS observes a real SIGKILL."""

    def __init__(self, chunk: int):
        super().__init__(f"campaign killed at chunk boundary {chunk}")
        self.chunk = chunk


class SyntheticOOM(RuntimeError):
    """Chaos allocation failure; classifies as ``FailureKind.OOM``."""

    def __init__(self, chunk: int):
        super().__init__(
            f"RESOURCE_EXHAUSTED: synthetic chaos OOM at chunk {chunk} "
            "(out of memory while trying to allocate lane buffers)"
        )
        self.chunk = chunk


class SyntheticDeviceLoss(RuntimeError):
    """Chaos device loss; classifies as ``FailureKind.DEVICE_LOSS``.

    ``n_lost`` is how many devices of the current set dropped (the
    campaign rebuilds its dispatch on the survivors)."""

    def __init__(self, chunk: int, n_lost: int = 1):
        super().__init__(
            f"DEVICE_LOST: synthetic chaos device loss at chunk {chunk} "
            f"({n_lost} device(s) dropped from the dispatch set)"
        )
        self.chunk = chunk
        self.n_lost = n_lost


class SyntheticJaxFailure(RuntimeError):
    """Chaos engine failure with no recognizable status code; classifies
    as ``FailureKind.TRANSIENT`` and — fired persistently — exhausts the
    retry budget, forcing the engine="jax" -> "batch" degradation."""

    def __init__(self, chunk: int):
        super().__init__(
            f"synthetic persistent jax engine failure at chunk {chunk}"
        )
        self.chunk = chunk


@dataclass
class ChaosInjector:
    """Deterministic chunk-boundary chaos for campaign sweeps.

    Two firing modes compose:

    * **scheduled** — ``kill_at`` / ``oom_at`` / ``device_loss_at`` name
      chunk indices (fired once, in incarnation 0, on the first attempt
      of that chunk: a retry or a resumed process proceeds past them,
      which is what lets tests assert the recovery completed);
      ``jax_fail_at`` fires from that chunk index onward on *every*
      attempt while the engine is still "jax" (a persistent engine bug),
      or on first attempts only with ``jax_fail_persistent=False``.
    * **probabilistic** — ``p_kill`` / ``p_oom`` / ``p_device_loss`` are
      per-chunk-boundary firing probabilities drawn from the SplitMix64
      counter stream keyed on ``(seed, incarnation, chunk)``: the same
      seed replays the same chaos, while a resumed incarnation sees
      fresh draws (so a kill is not deterministically re-fired forever).
      ``max_fires`` bounds the total probabilistic fires (fuzz budget).

    ``kill_mode`` selects how process death is simulated: ``"raise"``
    raises :class:`CampaignKilled` (in-process tests), ``"sigkill"``
    sends the hosting process a real ``SIGKILL`` (subprocess tests — no
    atexit handlers, no flushes, exactly a preemption)."""

    seed: int = 0
    p_kill: float = 0.0
    p_oom: float = 0.0
    p_device_loss: float = 0.0
    kill_at: Sequence[int] = ()
    oom_at: Sequence[int] = ()
    device_loss_at: Sequence[int] = ()
    jax_fail_at: Optional[int] = None
    jax_fail_persistent: bool = True
    kill_mode: str = "raise"
    max_fires: Optional[int] = None
    #: (chunk, kind) pairs already fired by this injector instance
    fired: Set[Tuple[int, str]] = field(default_factory=set)
    n_fires: int = 0

    def __post_init__(self):
        if self.kill_mode not in ("raise", "sigkill"):
            raise ValueError(
                f"unknown kill_mode {self.kill_mode!r} "
                "(expected 'raise' or 'sigkill')"
            )

    # ------------------------------------------------------------------ #
    def _u(self, incarnation: int, chunk: int, slot: int) -> float:
        """One deterministic U(0,1) draw per (incarnation, chunk, slot)."""
        ctr = (
            ((incarnation & 0xFFFF) << 40)
            | ((chunk & 0xFFFFFFFF) << 8)
            | (slot & 0xFF)
        )
        hi, _lo = splitmix64(
            np.uint64(self.seed & 0xFFFFFFFFFFFFFFFF), np.uint64(ctr)
        )
        return float(uniform24(hi))

    def _kill(self, chunk: int) -> None:
        if self.kill_mode == "sigkill":
            os.kill(os.getpid(), signal.SIGKILL)  # pragma: no cover
        raise CampaignKilled(chunk)

    def _budget_ok(self) -> bool:
        return self.max_fires is None or self.n_fires < self.max_fires

    # ------------------------------------------------------------------ #
    def at_chunk_boundary(
        self,
        chunk: int,
        *,
        incarnation: int = 0,
        attempt: int = 0,
        engine: str = "jax",
    ) -> None:
        """Fire chaos (by raising) for the chunk about to be dispatched.

        ``attempt`` is the dispatch attempt of this chunk (0 = first);
        ``engine`` is the campaign's *current* engine, so a persistent
        jax failure stops firing once the campaign degraded to "batch"
        (the synthetic bug lives in the jax path)."""
        # persistent engine failure: every attempt while still on jax
        if (
            self.jax_fail_at is not None
            and engine == "jax"
            and chunk >= self.jax_fail_at
            and (self.jax_fail_persistent or attempt == 0)
        ):
            raise SyntheticJaxFailure(chunk)
        if attempt:
            return  # scheduled/probabilistic chaos fires once per chunk
        if incarnation == 0:
            if chunk in self.kill_at and (chunk, "kill") not in self.fired:
                self.fired.add((chunk, "kill"))
                self._kill(chunk)
            if chunk in self.oom_at and (chunk, "oom") not in self.fired:
                self.fired.add((chunk, "oom"))
                raise SyntheticOOM(chunk)
            if chunk in self.device_loss_at and (
                chunk, "devloss"
            ) not in self.fired:
                self.fired.add((chunk, "devloss"))
                raise SyntheticDeviceLoss(chunk)
        if self.p_kill and self._budget_ok() and (
            self._u(incarnation, chunk, 0) < self.p_kill
        ):
            self.n_fires += 1
            self._kill(chunk)
        if self.p_oom and self._budget_ok() and (
            self._u(incarnation, chunk, 1) < self.p_oom
        ):
            self.n_fires += 1
            raise SyntheticOOM(chunk)
        if self.p_device_loss and self._budget_ok() and (
            self._u(incarnation, chunk, 2) < self.p_device_loss
        ):
            self.n_fires += 1
            raise SyntheticDeviceLoss(chunk)
