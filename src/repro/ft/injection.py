"""Fault injection for the executor.

Real fleet faults land as SIGTERMs / slice-health events between or during
steps; here they surface as :class:`SimulatedFault` raised at step
boundaries when the (simulated or wall) clock crosses a fault time from an
:class:`EventTrace` — the same trace generator the paper's simulator uses,
so executor behaviour is directly comparable to the analytic model.
"""

from __future__ import annotations

from typing import List, Optional

from ..core.events import EventTrace

__all__ = ["SimulatedFault", "FaultInjector"]


class SimulatedFault(RuntimeError):
    def __init__(self, time: float, predicted: bool):
        super().__init__(f"injected fault at t={time:.1f}s (predicted={predicted})")
        self.time = time
        self.predicted = predicted


class FaultInjector:
    """Raises when execution crosses the next fault time."""

    def __init__(self, trace: EventTrace, cancelled: Optional[set] = None):
        self.fault_times: List[float] = [f.time for f in trace.faults]
        self.predicted = [f.predicted for f in trace.faults]
        self._i = 0
        self.cancelled = cancelled if cancelled is not None else set()

    def cancel(self, fault_time: float) -> None:
        """Migration vacated the node: this fault no longer hits us."""
        self.cancelled.add(fault_time)

    def peek(self) -> Optional[float]:
        while self._i < len(self.fault_times) and (
            self.fault_times[self._i] in self.cancelled
        ):
            self._i += 1
        if self._i >= len(self.fault_times):
            return None
        return self.fault_times[self._i]

    def check(self, now: float) -> None:
        """Raise if a fault occurred at or before ``now``."""
        nxt = self.peek()
        if nxt is not None and nxt <= now:
            predicted = self.predicted[self._i]
            self._i += 1
            raise SimulatedFault(nxt, predicted)
