"""Failure classification and deterministic retry/backoff.

One classifier serves every recovery loop in the repo — the campaign
runner's chunk-boundary dispatch retries (:mod:`repro.ft.campaign`) and
the executor's restore-tier fallback (:mod:`repro.ft.executor`).  A
dispatch failure is mapped to a :class:`FailureKind` by exception type
and message (the XLA runtime encodes its status codes in the message
text, so string matching is the portable contract across jax versions),
and a :class:`RetryPolicy` prices the retry: jittered exponential
backoff with a bounded attempt budget.

The jitter is drawn from the repo's counter-based SplitMix64 stream
(:func:`repro.core.events.splitmix64`), not wall-clock entropy, so a
resumed campaign replays the *same* backoff schedule as the run it
replaces — retries never perturb reproducibility.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable

import numpy as np

from ..core.events import splitmix64, uniform24

__all__ = ["FailureKind", "classify_failure", "RetryPolicy"]


class FailureKind(Enum):
    #: allocation pressure: shrink the resident-lane footprint and retry
    OOM = "oom"
    #: a device dropped out: rebuild the dispatch on the survivors
    DEVICE_LOSS = "device_loss"
    #: unknown runtime error: retry as-is under the backoff budget
    TRANSIENT = "transient"
    #: programming/config error: never retried, propagate immediately
    FATAL = "fatal"


#: message fragments the XLA runtime uses for allocation failures
_OOM_PATTERNS = (
    "RESOURCE_EXHAUSTED",
    "RESOURCE EXHAUSTED",
    "Resource exhausted",
    "Out of memory",
    "out of memory",
    "OOM",
)

#: message fragments for device health failures
_DEVICE_LOSS_PATTERNS = (
    "DEVICE_LOST",
    "device lost",
    "Device lost",
    "device is lost",
    "device unavailable",
    "NCCL",
)

#: exception types that signal a bug or bad configuration, not a fault
_FATAL_TYPES = (TypeError, ValueError, KeyError, AttributeError, IndexError)


def classify_failure(exc: BaseException) -> FailureKind:
    """Map an exception raised by a dispatch (or restore) to a
    :class:`FailureKind`.  Synthetic chaos exceptions carry the same
    message fragments as their real counterparts, so they classify
    through this one function — the recovery paths under test are the
    production paths."""
    kind = getattr(exc, "failure_kind", None)
    if isinstance(kind, FailureKind):
        return kind
    msg = f"{type(exc).__name__}: {exc}"
    if any(p in msg for p in _DEVICE_LOSS_PATTERNS):
        return FailureKind.DEVICE_LOSS
    if any(p in msg for p in _OOM_PATTERNS):
        return FailureKind.OOM
    if isinstance(exc, _FATAL_TYPES):
        return FailureKind.FATAL
    return FailureKind.TRANSIENT


@dataclass
class RetryPolicy:
    """Jittered exponential backoff with a bounded per-site budget.

    ``max_attempts`` counts tries of one logical operation (a chunk
    dispatch, a restore tier); attempt ``k`` (0-based) sleeps
    ``base * factor**k * (1 + jitter * u)`` where ``u ~ U(0,1)`` comes
    from the seeded SplitMix64 counter stream — deterministic given
    (seed, counter), so schedules replay bit-exactly across resumes.
    ``sleep`` is injectable for tests (and for the executor's simulated
    clock, which advances virtual time instead of stalling)."""

    max_attempts: int = 4
    base: float = 0.05
    factor: float = 2.0
    jitter: float = 0.5
    seed: int = 0
    sleep: Callable[[float], None] = field(default=time.sleep, repr=False)

    def backoff(self, attempt: int, counter: int) -> float:
        """Backoff duration (seconds) before retry ``attempt``."""
        hi, _lo = splitmix64(np.uint64(self.seed & 0xFFFFFFFFFFFFFFFF),
                             np.uint64(counter & 0xFFFFFFFFFFFFFFFF))
        u = float(uniform24(hi))
        return self.base * (self.factor ** attempt) * (1.0 + self.jitter * u)

    def pause(self, attempt: int, counter: int) -> float:
        """Sleep the backoff for (attempt, counter); returns the
        duration so callers can attribute the stall (e.g. to a
        :class:`~repro.ft.executor.WasteLedger` bucket)."""
        dt = self.backoff(attempt, counter)
        self.sleep(dt)
        return dt
