"""Quickstart: the paper's checkpointing calculus in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import (
    Platform,
    PredictorModel,
    best_policy,
    optimize_exact,
    simulate_many,
    t_extr,
    t_young,
)
from repro.core import simulator as S
from repro.core.predictor import TABLE3_PREDICTORS

MN = 60.0

# A 2^16-processor platform: individual MTBF 125 years -> platform MTBF ~17 h
plat = Platform(mu=1000 * MN, C=10 * MN, D=1 * MN, R=10 * MN, M=5 * MN)

print("== Optimal periods (the unified formula sqrt(2 mu C / (1 - r q))) ==")
print(f"  Young (no prediction):     T = {t_extr(plat.mu, plat.C)/60:7.1f} mn")
for name in ["paper-accurate", "paper-limited", "zheng-lead300", "liang-6h"]:
    pred = TABLE3_PREDICTORS[name]
    t1 = t_extr(plat.mu, plat.C, pred.recall, 1.0)
    pol = optimize_exact(plat, pred)
    print(
        f"  {name:16s} (r={pred.recall:.2f}, p={pred.precision:.2f}): "
        f"T = {t1/60:7.1f} mn, q*={pol.q}, waste {pol.waste:.3f}"
    )

print("\n== Window strategies (I = 3000 s) ==")
pred = PredictorModel(0.85, 0.82, window=3000.0)
pol = best_policy(plat, pred)
print(f"  best strategy: {pol.strategy} (q={pol.q}, T_R={pol.T_R:.0f}s, "
      f"T_P={pol.T_P}, waste={pol.waste:.3f})")

print("\n== Simulation check (20 platform-days of work) ==")
work = 20 * 86400.0
for label, strat, pm in [
    ("Young", S.young(plat), PredictorModel(0.0, 1.0)),
    ("ExactPrediction", S.exact_prediction(plat, PredictorModel(0.85, 0.82)),
     PredictorModel(0.85, 0.82)),
]:
    res = simulate_many(work, plat, strat, pm, n_runs=10, seed=0)
    waste = float(np.mean([r.waste for r in res]))
    days = float(np.mean([r.makespan for r in res])) / 86400
    print(f"  {label:16s}: waste {waste:.4f}, makespan {days:.1f} days")
print("\nPrediction pays: same work, fewer wasted cycles.")
