"""Reproduce the paper's Figure-4 trend as a terminal table: waste vs N
for Young / ExactPrediction / NoCkptI, analytic + simulated.

The simulated columns come from one batched sweep: every (N, strategy)
point is a cell of a single grid, executed by the vectorized
lane-per-trace engine (see repro.experiments).

    PYTHONPATH=src python examples/simulate_cluster.py
"""

import sys

sys.path.insert(0, "src")

from repro.configs.paper import C, D, MU_IND, N_RANGE, R
from repro.core import Platform, PredictorModel, optimize
from repro.core import simulator as S
from repro.experiments import ExperimentCell, run_cells

pred = PredictorModel(0.85, 0.82, window=300.0)
work = 6 * 86400.0

cells = []
for n in N_RANGE:
    plat = Platform(mu=MU_IND / n, C=C, D=D, R=R)
    cells.append(
        ExperimentCell(
            f"exact/N{n}", work, plat, pred, S.exact_prediction(plat, pred)
        )
    )
    cells.append(
        ExperimentCell(f"nockpt/N{n}", work, plat, pred, S.nockpt(plat, pred))
    )
sweep = run_cells(cells, n_runs=6, seed=1)

print(f"{'N':>8} {'mu(mn)':>8} | {'Young':>7} {'Exact(an)':>9} "
      f"{'Exact(sim)':>10} {'NoCkptI(sim)':>12} | gain")
for n in N_RANGE:
    plat = Platform(mu=MU_IND / n, C=C, D=D, R=R)
    wy = optimize("exact", plat, PredictorModel(0.0, 1.0)).waste
    wa = optimize("exact", plat, PredictorModel(pred.recall, pred.precision)).waste
    we = sweep[f"exact/N{n}"].mean_waste
    wn = sweep[f"nockpt/N{n}"].mean_waste
    print(
        f"{n:>8} {plat.mu/60:>8.0f} | {wy:>7.3f} {wa:>9.3f} {we:>10.3f} "
        f"{wn:>12.3f} | {100*(1-we/max(wy,1e-9)):>4.0f}%"
    )
print(f"\nWaste grows with N; prediction's advantage grows faster (paper Fig 4)."
      f"  [sweep: {sweep.grid.n_lanes} lanes in {sweep.wall_time_s:.1f}s]")
