"""Reproduce the paper's Figure-4 trend as a terminal table: waste vs N
for Young / ExactPrediction / NoCkptI, analytic + simulated.

    PYTHONPATH=src python examples/simulate_cluster.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.configs.paper import C, D, MU_IND, N_RANGE, R
from repro.core import Platform, PredictorModel, optimize_exact, simulate_many
from repro.core import simulator as S

pred = PredictorModel(0.85, 0.82, window=300.0)
work = 6 * 86400.0

print(f"{'N':>8} {'mu(mn)':>8} | {'Young':>7} {'Exact(an)':>9} "
      f"{'Exact(sim)':>10} {'NoCkptI(sim)':>12} | gain")
for n in N_RANGE:
    plat = Platform(mu=MU_IND / n, C=C, D=D, R=R)
    wy = optimize_exact(plat, PredictorModel(0.0, 1.0)).waste
    wa = optimize_exact(plat, PredictorModel(pred.recall, pred.precision)).waste
    sim_e = simulate_many(
        work, plat, S.exact_prediction(plat, pred), pred, n_runs=6, seed=1
    )
    sim_n = simulate_many(work, plat, S.nockpt(plat, pred), pred, n_runs=6, seed=1)
    we = float(np.mean([r.waste for r in sim_e]))
    wn = float(np.mean([r.waste for r in sim_n]))
    print(
        f"{n:>8} {plat.mu/60:>8.0f} | {wy:>7.3f} {wa:>9.3f} {we:>10.3f} "
        f"{wn:>12.3f} | {100*(1-we/max(wy,1e-9)):>4.0f}%"
    )
print("\nWaste grows with N; prediction's advantage grows faster (paper Fig 4).")
