"""Fault-tolerant batched serving example (decode with cache snapshots).

    PYTHONPATH=src python examples/serve_ft.py
"""

import subprocess
import sys

subprocess.run(
    [
        sys.executable,
        "-m",
        "repro.launch.serve",
        "--arch", "qwen2-0.5b",
        "--requests", "4",
        "--prompt-len", "24",
        "--gen", "40",
        "--snapshot-every", "8",
        "--inject-faults",
        "--fault-mtbf", "3",
    ],
    env={"PYTHONPATH": "src"},
    check=True,
)
