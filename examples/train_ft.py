"""End-to-end driver: train a reduced model under injected faults, with the
paper's prediction-aware checkpointing vs Young on the SAME fault trace.

    PYTHONPATH=src python examples/train_ft.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import AsyncCheckpointer, CheckpointStore, latest_step
from repro.core.events import make_event_trace
from repro.core.predictor import SimulatedPredictor
from repro.core.waste import Platform, PredictorModel
from repro.data.pipeline import SyntheticLMDataset
from repro.ft import FaultInjector, FaultTolerantExecutor, SimClock
from repro.launch.steps import build_model, build_train_step
from repro.models.layers import RuntimeFlags
from repro.optim.adamw import adamw_init

STEPS = 60
cfg = configs.get("smollm-135m").reduced()
model, _ = build_model(cfg, mesh=None, flags=RuntimeFlags(dense_attn_max=256))
inner = jax.jit(build_train_step(model, lr=1e-3))
data = SyntheticLMDataset(cfg.vocab_size, 64, 4, seed=1)

plat = Platform(mu=40.0, C=2.0, D=0.5, R=1.0)  # harsh simulated platform
pm = PredictorModel(0.85, 0.82, window=1.0, lead=10.0)


def run(strategy: str, recall: float, ckpt_dir: str):
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw_init(params)}
    losses = {}

    def step_fn(st, k):
        batch = {kk: jnp.asarray(v) for kk, v in data.batch(k).items()}
        p, o, m = inner(st["params"], st["opt"], batch)
        losses[k] = float(m["loss"])
        return {"params": p, "opt": o}

    trace = make_event_trace(
        np.random.default_rng(7), horizon=1e5, mtbf=plat.mu,
        recall=recall, precision=pm.precision, window=pm.window, lead=pm.lead,
    )
    store = CheckpointStore(ckpt_dir)
    ckpt = AsyncCheckpointer(store)

    def restore_fn(_):
        s = latest_step(ckpt_dir)
        if s is None:
            p0 = model.init(jax.random.PRNGKey(0))
            return {"params": p0, "opt": adamw_init(p0)}
        return store.restore(s, target=jax.eval_shape(lambda: state))

    ex = FaultTolerantExecutor(
        step_fn=step_fn, state=state, platform=plat, pred_model=pm,
        predictor=SimulatedPredictor(trace, pm) if recall else None,
        checkpointer=ckpt, restore_fn=restore_fn,
        load_state=lambda st, t, k: t,
        injector=FaultInjector(trace), clock=SimClock(), step_time=1.0,
        strategy=strategy,
    )
    rep = ex.run(STEPS)
    return rep, losses


rep_y, losses_y = run("young", 0.0, "/tmp/ex_ft_young")
rep_p, losses_p = run("auto", pm.recall, "/tmp/ex_ft_pred")

print("Young           :", rep_y.summary())
print("Prediction-aware:", rep_p.summary())
print(f"\nfinal losses converge identically (deterministic replay): "
      f"{losses_y[STEPS-1]:.4f} vs {losses_p[STEPS-1]:.4f}")
print(f"waste reduction: {100*(1 - rep_p.ledger.waste()/rep_y.ledger.waste()):.0f}%")
